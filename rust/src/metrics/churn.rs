//! Prediction churn (paper §3.5, Table 1).
//!
//! Churn is estimated as the mean absolute difference between the
//! predictions of two retrains of the same training procedure on a fixed
//! validation set; Table 1 reports mean ± half-range over 5 repeats.

use anyhow::{bail, Result};

/// Mean |a - b| between two prediction vectors on the same examples.
pub fn mean_abs_diff(a: &[f32], b: &[f32]) -> Result<f64> {
    if a.len() != b.len() || a.is_empty() {
        bail!("prediction vectors differ in length ({} vs {})", a.len(), b.len());
    }
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs() as f64)
        .sum();
    Ok(sum / a.len() as f64)
}

/// Aggregate of repeated churn measurements: mean ± half-range
/// (the paper's Table 1 convention, footnote 6).
#[derive(Debug, Clone)]
pub struct ChurnReport {
    pub samples: Vec<f64>,
}

impl ChurnReport {
    pub fn new() -> Self {
        ChurnReport { samples: vec![] }
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Half the range (max-min)/2 — the paper's ± column.
    pub fn half_range(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let max = self.samples.iter().cloned().fold(f64::MIN, f64::max);
        let min = self.samples.iter().cloned().fold(f64::MAX, f64::min);
        (max - min) / 2.0
    }
}

impl Default for ChurnReport {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mad_basic() {
        let d = mean_abs_diff(&[0.1, 0.5, 0.9], &[0.2, 0.5, 0.5]).unwrap();
        assert!((d - (0.1 + 0.0 + 0.4) / 3.0).abs() < 1e-7);
    }

    #[test]
    fn mad_identical_is_zero() {
        assert_eq!(mean_abs_diff(&[0.3; 10], &[0.3; 10]).unwrap(), 0.0);
    }

    #[test]
    fn mad_length_mismatch() {
        assert!(mean_abs_diff(&[0.1], &[0.1, 0.2]).is_err());
        assert!(mean_abs_diff(&[], &[]).is_err());
    }

    #[test]
    fn report_mean_and_half_range() {
        let mut r = ChurnReport::new();
        for v in [0.02, 0.03, 0.04] {
            r.push(v);
        }
        assert!((r.mean() - 0.03).abs() < 1e-12);
        assert!((r.half_range() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn report_empty_is_nan() {
        let r = ChurnReport::new();
        assert!(r.samples.is_empty());
        assert!(r.mean().is_nan());
        assert!(r.half_range().is_nan());
    }

    #[test]
    fn report_single_sample() {
        let mut r = ChurnReport::default();
        r.push(0.0125);
        assert_eq!(r.mean(), 0.0125);
        assert_eq!(r.half_range(), 0.0);
    }

    #[test]
    fn identical_predictions_report_zero_churn() {
        // two "retrains" that agree exactly (the paper's ideal) aggregate
        // to zero mean and zero spread, not NaN or a denormal artifact
        let preds: Vec<f32> = (0..64).map(|i| (i as f32 * 0.013).sin() * 0.5 + 0.5).collect();
        let mut r = ChurnReport::new();
        for _ in 0..5 {
            r.push(mean_abs_diff(&preds, &preds).unwrap());
        }
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.half_range(), 0.0);
    }

    #[test]
    fn report_negative_and_mixed_samples_half_range() {
        // half_range is (max-min)/2 regardless of sign or order
        let mut r = ChurnReport::new();
        for v in [0.5, -0.5, 0.0] {
            r.push(v);
        }
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.half_range(), 0.5);
    }
}

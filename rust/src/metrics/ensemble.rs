//! Ensemble scoring (the Fig 2a "two-way ensemble" comparison arm).
//!
//! The ensemble averages the *predictive distributions* of its members and
//! is scored with the same token cross entropy: loss = −log p̄[target].
//! Member probabilities come from each member's `predict` executable; the
//! averaging and scoring happen here on the host, since no single artifact
//! owns both members' parameters.

use crate::runtime::Tensor;
use anyhow::{bail, Result};

/// Token targets in the probs layout.
///
/// `predict` emits probs as `[T*B, V]` time-major (row `t*B + b`); targets
/// for row `(t, b)` are `tokens[b, t+1]`.
pub fn lm_targets_time_major(tokens: &Tensor) -> Result<Vec<usize>> {
    let shape = tokens.shape();
    if shape.len() != 2 {
        bail!("tokens must be [B, T+1]");
    }
    let (b, t1) = (shape[0], shape[1]);
    let t = t1 - 1;
    let data = tokens.as_i32()?;
    let mut targets = Vec::with_capacity(t * b);
    for ti in 0..t {
        for bi in 0..b {
            targets.push(data[bi * t1 + ti + 1] as usize);
        }
    }
    Ok(targets)
}

/// Mean token cross entropy of an averaged-probability ensemble.
///
/// `member_probs`: one `[T*B, V]` tensor per member, same batch.
pub fn lm_ensemble_eval(member_probs: &[Tensor], tokens: &Tensor) -> Result<f64> {
    if member_probs.is_empty() {
        bail!("empty ensemble");
    }
    let targets = lm_targets_time_major(tokens)?;
    let shape = member_probs[0].shape().to_vec();
    if shape.len() != 2 || shape[0] != targets.len() {
        bail!(
            "probs shape {:?} inconsistent with {} targets",
            shape,
            targets.len()
        );
    }
    let v = shape[1];
    let n = member_probs.len() as f64;
    let mut total = 0.0f64;
    for (row, &target) in targets.iter().enumerate() {
        if target >= v {
            bail!("target {target} out of vocab {v}");
        }
        let mut p = 0.0f64;
        for m in member_probs {
            p += m.as_f32()?[row * v + target] as f64;
        }
        total += -(p / n).max(1e-12).ln();
    }
    Ok(total / targets.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_layout() {
        // B=2, T=2: tokens[b, t]
        let tokens = Tensor::i32(&[2, 3], vec![10, 11, 12, 20, 21, 22]).unwrap();
        let t = lm_targets_time_major(&tokens).unwrap();
        // rows: (t0,b0)=11, (t0,b1)=21, (t1,b0)=12, (t1,b1)=22
        assert_eq!(t, vec![11, 21, 12, 22]);
    }

    #[test]
    fn ensemble_of_identical_is_member_loss() {
        let tokens = Tensor::i32(&[1, 2], vec![0, 1]).unwrap();
        let probs = Tensor::f32(&[1, 3], vec![0.2, 0.5, 0.3]).unwrap();
        let single = lm_ensemble_eval(&[probs.clone()], &tokens).unwrap();
        let double = lm_ensemble_eval(&[probs.clone(), probs], &tokens).unwrap();
        assert!((single - double).abs() < 1e-9);
        assert!((single - (-(0.5f64).ln())).abs() < 1e-6);
    }

    #[test]
    fn averaging_helps_disagreeing_members() {
        // One confident-wrong member + one confident-right member: the
        // average's log loss is far below the mean of individual losses.
        let tokens = Tensor::i32(&[1, 2], vec![0, 0]).unwrap();
        let right = Tensor::f32(&[1, 2], vec![0.99, 0.01]).unwrap();
        let wrong = Tensor::f32(&[1, 2], vec![0.01, 0.99]).unwrap();
        let ens = lm_ensemble_eval(&[right.clone(), wrong.clone()], &tokens).unwrap();
        let l_right = lm_ensemble_eval(&[right], &tokens).unwrap();
        let l_wrong = lm_ensemble_eval(&[wrong], &tokens).unwrap();
        assert!(ens < (l_right + l_wrong) / 2.0);
    }

    #[test]
    fn bad_shapes_error() {
        let tokens = Tensor::i32(&[1, 2], vec![0, 5]).unwrap();
        let probs = Tensor::f32(&[1, 3], vec![0.2, 0.5, 0.3]).unwrap();
        assert!(lm_ensemble_eval(&[probs], &tokens).is_err()); // target 5 >= vocab 3
        assert!(lm_ensemble_eval(&[], &tokens).is_err());
    }
}

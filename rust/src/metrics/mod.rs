//! Metrics: CSV emission, curve summaries, churn, serving latency, and
//! ensemble scoring.

pub mod churn;
pub mod csv;
pub mod ensemble;
pub mod latency;

pub use churn::{mean_abs_diff, ChurnReport};
pub use csv::CsvWriter;
pub use ensemble::lm_ensemble_eval;
pub use latency::LatencyHistogram;

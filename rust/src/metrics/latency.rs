//! Log-linear latency histogram for the serving tier (p50/p99/p999).
//!
//! Request latencies span four orders of magnitude between an in-memory
//! cache hit and a queue-backed tail, so fixed-width buckets either
//! blur the tail or waste memory on the head. [`LatencyHistogram`]
//! buckets `log2`-style with 4 linear sub-buckets per octave (~19%
//! relative resolution at every scale, 256 counters total) — the
//! standard HDR-histogram compromise, sized for a serving process that
//! records millions of samples without allocation after construction.
//!
//! Percentiles are bucket lower bounds, so reported values are
//! conservative (never above the true percentile by more than one
//! bucket width). The histogram is a plain value type; the serving
//! tier wraps it in its own lock.

use std::time::Duration;

/// Sub-buckets per power of two (fixed; 4 ⇒ ≤ ~19% relative error).
const SUBS: usize = 4;
/// Octaves covered: 2^0 .. 2^63 nanoseconds.
const OCTAVES: usize = 64;

/// Log-linear histogram of durations with constant memory.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    total_ns: u128,
    max_ns: u64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; SUBS * OCTAVES],
            count: 0,
            total_ns: 0,
            max_ns: 0,
        }
    }

    fn index(ns: u64) -> usize {
        if ns < SUBS as u64 {
            return ns as usize; // exact for the first few nanoseconds
        }
        let exp = 63 - ns.leading_zeros() as usize;
        let sub = ((ns >> (exp - 2)) & 3) as usize; // top-2 mantissa bits
        (exp * SUBS + sub).min(SUBS * OCTAVES - 1)
    }

    /// Lower bound of the bucket at `idx` in nanoseconds (the value
    /// percentiles report).
    fn lower_bound(idx: usize) -> u64 {
        if idx < SUBS {
            return idx as u64;
        }
        let exp = idx / SUBS;
        let sub = idx % SUBS;
        (1u64 << exp) + ((sub as u64) << (exp - 2))
    }

    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[Self::index(ns)] += 1;
        self.count += 1;
        self.total_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.total_ns += other.total_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds (NaN when empty).
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.total_ns as f64 / self.count as f64 / 1e9
    }

    /// Largest recorded sample in seconds (NaN when empty).
    pub fn max_s(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.max_ns as f64 / 1e9
    }

    /// The `q`-quantile (`q` in [0,1]) in seconds: lower bound of the
    /// first bucket whose cumulative count covers `q·count`. NaN when
    /// empty.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        // rank of the target sample, 1-based; q=1.0 → the max bucket
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::lower_bound(i) as f64 / 1e9;
            }
        }
        self.max_ns as f64 / 1e9
    }

    pub fn p50_s(&self) -> f64 {
        self.quantile_s(0.50)
    }

    pub fn p99_s(&self) -> f64 {
        self.quantile_s(0.99)
    }

    pub fn p999_s(&self) -> f64 {
        self.quantile_s(0.999)
    }

    /// One-line rendering in milliseconds, the serving CLI's format.
    pub fn summary_ms(&self) -> String {
        format!(
            "n={} p50={:.3}ms p99={:.3}ms p999={:.3}ms max={:.3}ms",
            self.count,
            self.p50_s() * 1e3,
            self.p99_s() * 1e3,
            self.p999_s() * 1e3,
            self.max_s() * 1e3
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_nan() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.p50_s().is_nan());
        assert!(h.mean_s().is_nan());
        assert!(h.max_s().is_nan());
    }

    #[test]
    fn single_sample_every_quantile() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
            let v = h.quantile_s(q);
            // lower bound of the sample's bucket: within 19% below 100µs
            assert!(v <= 100e-6 && v >= 80e-6, "q={q} v={v}");
        }
        assert!((h.max_s() - 100e-6).abs() < 1e-12);
    }

    #[test]
    fn bucket_bounds_are_monotone_and_tight() {
        // lower_bound(index(ns)) <= ns for every probe, with bounded
        // relative error
        for shift in 0..50u64 {
            for off in [0u64, 1, 3] {
                let ns = (1u64 << shift).saturating_add(off << (shift.saturating_sub(3)));
                let lb = LatencyHistogram::lower_bound(LatencyHistogram::index(ns));
                assert!(lb <= ns, "ns={ns} lb={lb}");
                if ns >= SUBS as u64 {
                    assert!((ns - lb) as f64 / ns as f64 <= 0.25, "ns={ns} lb={lb}");
                }
            }
        }
    }

    #[test]
    fn percentiles_order_and_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=1000u64 {
            let h = if i % 2 == 0 { &mut a } else { &mut b };
            h.record(Duration::from_micros(i));
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        let (p50, p99, p999) = (a.p50_s(), a.p99_s(), a.p999_s());
        assert!(p50 <= p99 && p99 <= p999);
        // p50 of uniform 1..=1000µs sits near 500µs (bucket lower bound)
        assert!(p50 > 300e-6 && p50 <= 500e-6, "p50={p50}");
        assert!(p999 > 700e-6, "p999={p999}");
        assert!((a.mean_s() - 500.5e-6).abs() < 1e-6);
    }
}

//! Minimal CSV emission (no csv crate offline). Every experiment writes
//! its series under `results/` so figures can be re-plotted externally.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

pub struct CsvWriter {
    path: PathBuf,
    file: std::io::BufWriter<std::fs::File>,
    columns: usize,
    rows: usize,
}

impl CsvWriter {
    /// Create (truncate) a CSV with the given header.
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = CsvWriter {
            path: path.to_path_buf(),
            file: std::io::BufWriter::new(file),
            columns: header.len(),
            rows: 0,
        };
        writeln!(w.file, "{}", header.join(","))?;
        Ok(w)
    }

    /// Write one row of display-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        anyhow::ensure!(
            cells.len() == self.columns,
            "{}: row has {} cells, header has {}",
            self.path.display(),
            cells.len(),
            self.columns
        );
        for c in cells {
            anyhow::ensure!(
                !c.contains(',') && !c.contains('\n'),
                "cell {c:?} needs quoting; keep cells simple"
            );
        }
        writeln!(self.file, "{}", cells.join(","))?;
        self.rows += 1;
        Ok(())
    }

    /// Convenience: all-numeric row.
    pub fn num_row(&mut self, cells: &[f64]) -> Result<()> {
        self.row(&cells.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    pub fn rows_written(&self) -> usize {
        self.rows
    }

    pub fn finish(mut self) -> Result<PathBuf> {
        self.file.flush()?;
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("codistill_csv_{}_{name}", std::process::id()))
    }

    #[test]
    fn writes_header_and_rows() {
        let p = tmp("basic.csv");
        let mut w = CsvWriter::create(&p, &["step", "loss"]).unwrap();
        w.num_row(&[1.0, 0.5]).unwrap();
        w.row(&["2".into(), "0.25".into()]).unwrap();
        assert_eq!(w.rows_written(), 2);
        w.finish().unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "step,loss\n1,0.5\n2,0.25\n");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_wrong_arity_and_commas() {
        let p = tmp("arity.csv");
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        assert!(w.row(&["1,2".into(), "3".into()]).is_err());
        std::fs::remove_file(&p).ok();
    }
}

//! PJRT client wrapper with an executable cache.
//!
//! One [`Runtime`] per process; compiled executables are cached by artifact
//! path so that e.g. every simulated worker group shares a single compiled
//! `lm_grad` executable (PJRT executions are internally thread-safe).

use crate::runtime::exec::Executable;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Process-wide runtime: PJRT CPU client + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile an artifact: `<stem>.hlo.txt` + `<stem>.spec.txt`.
    ///
    /// `stem` is the path without the `.hlo.txt` suffix, e.g.
    /// `artifacts/lm/train_step`. Compiled executables are cached.
    pub fn load(&self, stem: &Path) -> Result<Arc<Executable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(stem) {
                return Ok(exe.clone());
            }
        }
        let hlo_path = stem.with_extension("hlo.txt");
        let spec_path = stem.with_extension("spec.txt");
        let exe = Arc::new(Executable::load(&self.client, &hlo_path, &spec_path)?);
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(stem.to_path_buf()).or_insert(exe).clone())
    }

    /// Number of distinct compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

//! An artifact bundle: one directory of AOT-lowered executables belonging to
//! a single model configuration.
//!
//! `python/compile/aot.py` writes, per model config, a directory like
//!
//! ```text
//! artifacts/lm_b64/
//!   bundle.txt          # key/value hyperparameters of the lowered model
//!   init.hlo.txt        + init.spec.txt
//!   grad.hlo.txt        + grad.spec.txt
//!   apply.hlo.txt       + apply.spec.txt
//!   train_step.hlo.txt  + train_step.spec.txt
//!   predict.hlo.txt     + predict.spec.txt
//!   eval.hlo.txt        + eval.spec.txt
//! ```
//!
//! A [`Bundle`] lazily loads + compiles executables from the directory
//! through the shared [`Runtime`] cache.

use crate::runtime::client::Runtime;
use crate::runtime::exec::Executable;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A directory of executables for one model configuration.
pub struct Bundle {
    dir: PathBuf,
    runtime: Arc<Runtime>,
    /// Parsed `bundle.txt` hyperparameters.
    meta: HashMap<String, String>,
}

impl Bundle {
    /// Open a bundle directory, parsing `bundle.txt`.
    pub fn open(runtime: Arc<Runtime>, dir: &Path) -> Result<Self> {
        if !dir.is_dir() {
            bail!(
                "bundle directory {} does not exist — run `make artifacts` first",
                dir.display()
            );
        }
        let meta_path = dir.join("bundle.txt");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let mut meta = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.splitn(2, ' ');
            let k = it.next().unwrap().to_string();
            let v = it.next().unwrap_or("").trim().to_string();
            meta.insert(k, v);
        }
        Ok(Bundle {
            dir: dir.to_path_buf(),
            runtime,
            meta,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load (and cache) the executable with the given stem name.
    pub fn exe(&self, name: &str) -> Result<Arc<Executable>> {
        self.runtime.load(&self.dir.join(name))
    }

    /// Whether the bundle ships an executable with this stem name.
    pub fn has(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).is_file()
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.runtime
    }

    /// Raw metadata value.
    pub fn meta(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(|s| s.as_str())
    }

    /// Metadata value parsed as usize.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        let v = self
            .meta(key)
            .with_context(|| format!("bundle {} missing meta key {key}", self.dir.display()))?;
        v.parse()
            .with_context(|| format!("bundle meta {key}={v} is not a usize"))
    }

    /// Metadata value parsed as f32.
    pub fn meta_f32(&self, key: &str) -> Result<f32> {
        let v = self
            .meta(key)
            .with_context(|| format!("bundle {} missing meta key {key}", self.dir.display()))?;
        v.parse()
            .with_context(|| format!("bundle meta {key}={v} is not an f32"))
    }
}

//! Name-addressed tensor collections.
//!
//! Executable signatures are flat positional lists, but the coordinator
//! thinks in named groups (`params.*`, `opt.*`, `state.*`, `tokens`, ...).
//! A [`TensorMap`] bridges the two: assemble inputs for a [`Spec`] by name,
//! capture outputs back into names, move whole prefixes between maps
//! (e.g. teacher params into a student's predict call).
//!
//! Storage is an ordered map so every prefix walk
//! ([`TensorMap::prefix_iter`]) is a sorted range scan: deterministic order
//! with no collect-sort round trip and no repeated hashing — the property
//! [`crate::runtime::flat::FlatLayout`] builds its name→offset plane on.

use crate::runtime::spec::Spec;
use crate::runtime::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::ops::Bound;

/// A named collection of host tensors (name-ordered).
#[derive(Debug, Clone, Default)]
pub struct TensorMap {
    map: BTreeMap<String, Tensor>,
}

impl TensorMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .with_context(|| format!("TensorMap missing {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map
            .get_mut(name)
            .with_context(|| format!("TensorMap missing {name:?}"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// All names in sorted order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Sorted, allocation-free iteration over the entries under a prefix
    /// (a range scan on the ordered map — no collect, no re-hash).
    pub fn prefix_iter<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a Tensor)> + 'a {
        self.map
            .range::<str, _>((Bound::Included(prefix), Bound::Unbounded))
            .take_while(move |(k, _)| k.starts_with(prefix))
            .map(|(k, t)| (k.as_str(), t))
    }

    /// Mutable variant of [`TensorMap::prefix_iter`] (in-place scaling,
    /// flat-plane scatter into existing storage).
    pub fn prefix_iter_mut<'a>(
        &'a mut self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a mut Tensor)> + 'a {
        self.map
            .range_mut::<str, _>((Bound::Included(prefix), Bound::Unbounded))
            .take_while(move |(k, _)| k.starts_with(prefix))
            .map(|(k, t)| (k.as_str(), t))
    }

    /// Build the positional input list for a spec, overlaying `extra`
    /// values (scalars like lr / distill_w) over this map's contents.
    pub fn assemble<'a>(
        &'a self,
        spec: &Spec,
        extra: &'a TensorMap,
    ) -> Result<Vec<&'a Tensor>> {
        let mut out = Vec::with_capacity(spec.inputs.len());
        for ts in &spec.inputs {
            let t = if let Some(t) = extra.map.get(&ts.name) {
                t
            } else if let Some(t) = self.map.get(&ts.name) {
                t
            } else {
                bail!(
                    "no tensor named {:?} for executable {} (have: {:?})",
                    ts.name,
                    spec.name,
                    {
                        let mut n: Vec<&str> =
                            self.map.keys().map(|s| s.as_str()).collect();
                        n.sort();
                        n
                    }
                );
            };
            if !t.matches(ts) {
                bail!(
                    "{}: tensor {:?} has {:?} {:?}, spec wants {:?} {:?}",
                    spec.name,
                    ts.name,
                    t.dtype(),
                    t.shape(),
                    ts.dtype,
                    ts.shape
                );
            }
            out.push(t);
        }
        Ok(out)
    }

    /// Capture executable outputs into a map keyed by the spec's names.
    pub fn from_outputs(spec: &Spec, outputs: Vec<Tensor>) -> Result<Self> {
        if outputs.len() != spec.outputs.len() {
            bail!(
                "{}: {} outputs for {} spec entries",
                spec.name,
                outputs.len(),
                spec.outputs.len()
            );
        }
        let mut map = BTreeMap::new();
        for (ts, t) in spec.outputs.iter().zip(outputs) {
            map.insert(ts.name.clone(), t);
        }
        Ok(TensorMap { map })
    }

    /// Copy every entry under `prefix` from `src`, optionally re-rooting it
    /// under `new_prefix` (e.g. teacher `params.*` -> student-side storage).
    /// When names and shapes already match, the copy happens in place
    /// (no map churn, no fresh allocations on the steady-state train loop).
    pub fn adopt_prefix(&mut self, src: &TensorMap, prefix: &str, new_prefix: &str) {
        for (k, v) in src.prefix_iter(prefix) {
            let rest = &k[prefix.len()..];
            // Fast path: same-name, same-shape destination — copy into its
            // existing storage instead of cloning a fresh tensor.
            let copied = if new_prefix == prefix {
                self.map.get_mut(k).is_some_and(|dst| copy_in_place(dst, v))
            } else {
                false
            };
            if !copied {
                self.map.insert(format!("{new_prefix}{rest}"), v.clone());
            }
        }
    }

    /// All entries under a prefix, sorted by name (deterministic order).
    /// Prefer [`TensorMap::prefix_iter`] on hot paths; this collects.
    pub fn prefix_entries(&self, prefix: &str) -> Vec<(&str, &Tensor)> {
        self.prefix_iter(prefix).collect()
    }

    /// Total f32/i32 elements under a prefix (parameter counting).
    pub fn prefix_numel(&self, prefix: &str) -> usize {
        self.prefix_iter(prefix).map(|(_, t)| t.numel()).sum()
    }

    /// Merge another map in, overwriting collisions.
    pub fn merge(&mut self, other: TensorMap) {
        self.map.extend(other.map);
    }

    /// Mean |a-b| over the f32 entries shared under a prefix — the churn
    /// metric generalized to parameter space (diagnostics).
    pub fn prefix_mean_abs_diff(&self, other: &TensorMap, prefix: &str) -> Result<f32> {
        let mut total = 0.0f64;
        let mut n = 0usize;
        for (k, t) in self.prefix_iter(prefix) {
            let o = other.get(k)?;
            if let (Ok(a), Ok(b)) = (t.as_f32(), o.as_f32()) {
                total += crate::runtime::vecops::abs_diff_sum(a, b);
                n += a.len();
            }
        }
        if n == 0 {
            bail!("no shared f32 entries under {prefix:?}");
        }
        Ok((total / n as f64) as f32)
    }
}

/// Overwrite `dst`'s storage with `src`'s when name-independent metadata
/// (shape + dtype) matches. Returns false (caller clones) otherwise.
fn copy_in_place(dst: &mut Tensor, src: &Tensor) -> bool {
    if dst.shape() != src.shape() {
        return false;
    }
    match (dst, src) {
        (Tensor::F32 { data: d, .. }, Tensor::F32 { data: s, .. }) => {
            d.copy_from_slice(s);
            true
        }
        (Tensor::I32 { data: d, .. }, Tensor::I32 { data: s, .. }) => {
            d.copy_from_slice(s);
            true
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::spec::Spec;

    fn sample_spec() -> Spec {
        Spec::parse(
            "spec-version 1\nname t\n\
             in params.a f32 2\nin lr f32 -\nin x i32 2\n\
             out params.a f32 2\nout loss f32 -\n",
        )
        .unwrap()
    }

    #[test]
    fn assemble_in_spec_order_with_extras() {
        let spec = sample_spec();
        let mut m = TensorMap::new();
        m.insert("params.a", Tensor::f32(&[2], vec![1.0, 2.0]).unwrap());
        m.insert("x", Tensor::i32(&[2], vec![3, 4]).unwrap());
        let mut extra = TensorMap::new();
        extra.insert("lr", Tensor::scalar_f32(0.1));
        let inputs = m.assemble(&spec, &extra).unwrap();
        assert_eq!(inputs.len(), 3);
        assert_eq!(inputs[0].as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(inputs[1].item_f32().unwrap(), 0.1);
        assert_eq!(inputs[2].as_i32().unwrap(), &[3, 4]);
    }

    #[test]
    fn assemble_missing_tensor_errors() {
        let spec = sample_spec();
        let m = TensorMap::new();
        assert!(m.assemble(&spec, &TensorMap::new()).is_err());
    }

    #[test]
    fn assemble_shape_mismatch_errors() {
        let spec = sample_spec();
        let mut m = TensorMap::new();
        m.insert("params.a", Tensor::f32(&[3], vec![1.0; 3]).unwrap());
        m.insert("x", Tensor::i32(&[2], vec![0, 0]).unwrap());
        let mut extra = TensorMap::new();
        extra.insert("lr", Tensor::scalar_f32(0.1));
        assert!(m.assemble(&spec, &extra).is_err());
    }

    #[test]
    fn outputs_roundtrip_and_prefix_ops() {
        let spec = sample_spec();
        let outs = vec![
            Tensor::f32(&[2], vec![5.0, 6.0]).unwrap(),
            Tensor::scalar_f32(0.25),
        ];
        let m = TensorMap::from_outputs(&spec, outs).unwrap();
        assert_eq!(m.get("loss").unwrap().item_f32().unwrap(), 0.25);
        assert_eq!(m.prefix_numel("params."), 2);

        let mut dst = TensorMap::new();
        dst.adopt_prefix(&m, "params.", "teacher.");
        assert_eq!(dst.get("teacher.a").unwrap().as_f32().unwrap(), &[5.0, 6.0]);
    }

    #[test]
    fn prefix_iter_sorted_and_bounded() {
        let mut m = TensorMap::new();
        for name in ["params.z", "params.a", "opt.m", "paramsx", "loss"] {
            m.insert(name, Tensor::scalar_f32(0.0));
        }
        let names: Vec<&str> = m.prefix_iter("params.").map(|(k, _)| k).collect();
        assert_eq!(names, vec!["params.a", "params.z"]);
        assert_eq!(m.prefix_iter("").count(), 5);
        assert_eq!(m.prefix_iter("nope.").count(), 0);
        // mutable variant reaches the same entries
        for (_, t) in m.prefix_iter_mut("params.") {
            t.scale(2.0).unwrap();
        }
        assert_eq!(m.prefix_entries("params.").len(), 2);
    }

    #[test]
    fn adopt_prefix_in_place_overwrite() {
        let mut dst = TensorMap::new();
        dst.insert("params.w", Tensor::f32(&[2], vec![0.0, 0.0]).unwrap());
        let mut src = TensorMap::new();
        src.insert("params.w", Tensor::f32(&[2], vec![5.0, 6.0]).unwrap());
        src.insert("params.new", Tensor::scalar_f32(1.0));
        dst.adopt_prefix(&src, "params.", "params.");
        assert_eq!(dst.get("params.w").unwrap().as_f32().unwrap(), &[5.0, 6.0]);
        assert_eq!(dst.get("params.new").unwrap().item_f32().unwrap(), 1.0);
    }

    #[test]
    fn prefix_mean_abs_diff() {
        let mut a = TensorMap::new();
        a.insert("params.w", Tensor::f32(&[2], vec![1.0, 3.0]).unwrap());
        let mut b = TensorMap::new();
        b.insert("params.w", Tensor::f32(&[2], vec![2.0, 1.0]).unwrap());
        let d = a.prefix_mean_abs_diff(&b, "params.").unwrap();
        assert!((d - 1.5).abs() < 1e-6);
    }
}

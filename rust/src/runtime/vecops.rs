//! Chunked slice kernels shared by every f32 hot path.
//!
//! The coordinator's reduction and checkpoint planes ([`Tensor`] maths,
//! [`crate::runtime::flat::FlatBuffer`], [`crate::sgd::allreduce`]) all
//! bottom out in these loops. Each kernel walks its slices in fixed-width
//! lanes ([`LANES`]) with an explicit remainder tail, which is the shape
//! LLVM reliably auto-vectorizes (and keeps f64 accumulators associative
//! per-lane, so results are deterministic regardless of caller chunking).
//!
//! Keep these free of bounds checks in the lane loop: the `chunks_exact` /
//! `zip` idiom below compiles to branchless SIMD on x86-64 and aarch64.

/// Lane width for the unrolled loops. Eight f32s = one AVX2 register.
pub const LANES: usize = 8;

/// Elements per parallel work unit: 64 KiB of f32 — small enough to stay
/// cache-resident while a chunk is summed across many workers, large
/// enough that thread spawn cost is noise (see `sgd::allreduce`).
pub const PAR_CHUNK: usize = 16 * 1024;

/// `dst += src`, elementwise. Panics if lengths differ (callers validate
/// shapes; slices of one flat plane always agree).
pub fn add(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "vecops::add length mismatch");
    let n = dst.len() - dst.len() % LANES;
    for (d, s) in dst[..n].chunks_exact_mut(LANES).zip(src[..n].chunks_exact(LANES)) {
        for i in 0..LANES {
            d[i] += s[i];
        }
    }
    for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
        *d += *s;
    }
}

/// `dst += k * src` — the axpy kernel behind teacher-probability averaging
/// (the distillation ramp) and the fused mean-reduce.
pub fn add_scaled(dst: &mut [f32], src: &[f32], k: f32) {
    assert_eq!(dst.len(), src.len(), "vecops::add_scaled length mismatch");
    let n = dst.len() - dst.len() % LANES;
    for (d, s) in dst[..n].chunks_exact_mut(LANES).zip(src[..n].chunks_exact(LANES)) {
        for i in 0..LANES {
            d[i] += k * s[i];
        }
    }
    for (d, s) in dst[n..].iter_mut().zip(&src[n..]) {
        *d += k * *s;
    }
}

/// `dst *= k`, elementwise.
pub fn scale(dst: &mut [f32], k: f32) {
    let n = dst.len() - dst.len() % LANES;
    for d in dst[..n].chunks_exact_mut(LANES) {
        for i in 0..LANES {
            d[i] *= k;
        }
    }
    for d in &mut dst[n..] {
        *d *= k;
    }
}

/// `dst = k * src`, elementwise (scaled copy; seeds the fused mean-reduce).
pub fn scaled_copy(dst: &mut [f32], src: &[f32], k: f32) {
    assert_eq!(dst.len(), src.len(), "vecops::scaled_copy length mismatch");
    for (d, s) in dst.iter_mut().zip(src) {
        *d = k * *s;
    }
}

/// Σ|a-b| with per-lane f64 accumulators (churn metric).
pub fn abs_diff_sum(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "vecops::abs_diff_sum length mismatch");
    let n = a.len() - a.len() % LANES;
    let mut acc = [0.0f64; LANES];
    for (x, y) in a[..n].chunks_exact(LANES).zip(b[..n].chunks_exact(LANES)) {
        for i in 0..LANES {
            acc[i] += (x[i] - y[i]).abs() as f64;
        }
    }
    let mut total: f64 = acc.iter().sum();
    for (x, y) in a[n..].iter().zip(&b[n..]) {
        total += (x - y).abs() as f64;
    }
    total
}

/// Σx² with per-lane f64 accumulators (L2 norms, divergence checks).
pub fn sq_sum(a: &[f32]) -> f64 {
    let n = a.len() - a.len() % LANES;
    let mut acc = [0.0f64; LANES];
    for x in a[..n].chunks_exact(LANES) {
        for i in 0..LANES {
            acc[i] += (x[i] as f64) * (x[i] as f64);
        }
    }
    let mut total: f64 = acc.iter().sum();
    for x in &a[n..] {
        total += (*x as f64) * (*x as f64);
    }
    total
}

/// One output chunk of the fused bucketed mean-reduce: for the window
/// `[start, start + out.len())` of the flat plane, compute
/// `out = scale * Σ_w parts[w][window]` in a single cache-resident pass.
pub fn mean_reduce_chunk(out: &mut [f32], parts: &[&[f32]], start: usize, scale: f32) {
    let end = start + out.len();
    scaled_copy(out, &parts[0][start..end], scale);
    for p in &parts[1..] {
        add_scaled(out, &p[start..end], scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lengths that straddle the lane boundary.
    const SIZES: [usize; 6] = [0, 1, 7, 8, 9, 1027];

    fn ramp(n: usize, k: f32) -> Vec<f32> {
        (0..n).map(|i| k * i as f32).collect()
    }

    #[test]
    fn add_matches_scalar_loop() {
        for n in SIZES {
            let mut a = ramp(n, 1.0);
            let b = ramp(n, 0.5);
            add(&mut a, &b);
            for (i, v) in a.iter().enumerate() {
                assert_eq!(*v, 1.5 * i as f32, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn add_scaled_matches_scalar_loop() {
        for n in SIZES {
            let mut a = ramp(n, 1.0);
            let b = ramp(n, 1.0);
            add_scaled(&mut a, &b, -2.0);
            for (i, v) in a.iter().enumerate() {
                assert_eq!(*v, -(i as f32), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn scale_and_scaled_copy() {
        for n in SIZES {
            let mut a = ramp(n, 1.0);
            scale(&mut a, 3.0);
            let mut c = vec![0.0; n];
            scaled_copy(&mut c, &ramp(n, 1.0), 3.0);
            assert_eq!(a, c, "n={n}");
        }
    }

    #[test]
    fn reductions_match_reference() {
        for n in SIZES {
            let a = ramp(n, 1.0);
            let b = ramp(n, 2.0);
            let want: f64 = (0..n).map(|i| i as f64).sum();
            assert!((abs_diff_sum(&a, &b) - want).abs() < 1e-9, "n={n}");
            let want_sq: f64 = (0..n).map(|i| (i as f64) * (i as f64)).sum();
            assert!((sq_sum(&a) - want_sq).abs() < want_sq.max(1.0) * 1e-12, "n={n}");
        }
    }

    #[test]
    fn mean_reduce_chunk_windows() {
        let w0 = ramp(100, 1.0);
        let w1 = ramp(100, 3.0);
        let parts: Vec<&[f32]> = vec![&w0, &w1];
        let mut out = vec![0.0f32; 10];
        mean_reduce_chunk(&mut out, &parts, 40, 0.5);
        for (i, v) in out.iter().enumerate() {
            let idx = (40 + i) as f32;
            assert!((v - 2.0 * idx).abs() < 1e-5, "i={i}: {v}");
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        let mut a = vec![0.0; 3];
        add(&mut a, &[1.0, 2.0]);
    }
}

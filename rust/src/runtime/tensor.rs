//! Host-side tensors crossing the PJRT boundary.
//!
//! A [`Tensor`] is a dense row-major array of f32 or i32 living on the
//! host. Conversions to/from [`xla::Literal`] happen only at the runtime
//! boundary; all coordinator code (allreduce, checkpoint store, data
//! pipeline) manipulates `Tensor`s directly.

use crate::runtime::spec::{DType, TensorSpec};
use crate::runtime::vecops;
use anyhow::{bail, Context, Result};

/// Dense host tensor. Row-major (C) layout, matching XLA's default.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    // ---------------------------------------------------------------- ctors

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            bail!(
                "f32 tensor shape {:?} wants {} elems, got {}",
                shape,
                numel,
                data.len()
            );
        }
        Ok(Tensor::F32 {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            bail!(
                "i32 tensor shape {:?} wants {} elems, got {}",
                shape,
                numel,
                data.len()
            );
        }
        Ok(Tensor::I32 {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::I32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        match spec.dtype {
            DType::F32 => Tensor::F32 {
                shape: spec.shape.clone(),
                data: vec![0.0; spec.numel()],
            },
            DType::I32 | DType::U32 => Tensor::I32 {
                shape: spec.shape.clone(),
                data: vec![0; spec.numel()],
            },
        }
    }

    pub fn full_f32(shape: &[usize], v: f32) -> Self {
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    // ------------------------------------------------------------ accessors

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            Tensor::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    /// Scalar value of a rank-0 (or single-element) f32 tensor.
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("item_f32 on tensor with {} elements", d.len());
        }
        Ok(d[0])
    }

    pub fn item_i32(&self) -> Result<i32> {
        let d = self.as_i32()?;
        if d.len() != 1 {
            bail!("item_i32 on tensor with {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Whether shape and dtype match a spec entry.
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        let dt_ok = match (self.dtype(), spec.dtype) {
            (DType::F32, DType::F32) => true,
            (DType::I32, DType::I32) | (DType::I32, DType::U32) => true,
            _ => false,
        };
        dt_ok && self.shape() == spec.shape.as_slice()
    }

    // ----------------------------------------------------- literal boundary

    /// Convert to an `xla::Literal` for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        lit.reshape(&dims)
            .with_context(|| format!("reshape literal to {:?}", self.shape()))
    }

    /// Convert from an `xla::Literal` (non-tuple) back to a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit
            .array_shape()
            .context("literal has no array shape (tuple?)")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>()?;
                Tensor::f32(&dims, data)
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>()?;
                Tensor::i32(&dims, data)
            }
            xla::ElementType::U32 => {
                // Reinterpret u32 as i32 on the host; the spec layer keeps
                // track of signedness where it matters (PRNG seeds).
                let data = lit.to_vec::<u32>()?;
                Tensor::i32(&dims, data.into_iter().map(|v| v as i32).collect())
            }
            xla::ElementType::F64 => {
                let data = lit.to_vec::<f64>()?;
                Tensor::f32(&dims, data.into_iter().map(|v| v as f32).collect())
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    // ---------------------------------------------------------------- maths

    /// Elementwise in-place add (for gradient reduction). Chunked through
    /// [`vecops`] so it vectorizes identically to the flat-plane path.
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.shape() != other.shape() {
            bail!(
                "add_assign shape mismatch: {:?} vs {:?}",
                self.shape(),
                other.shape()
            );
        }
        let dst = self.as_f32_mut()?;
        let src = other.as_f32()?;
        vecops::add(dst, src);
        Ok(())
    }

    /// Elementwise in-place axpy: `self += k * other` — folds a weight into
    /// the accumulation pass (teacher-probability averaging, ramp mixing).
    pub fn add_scaled(&mut self, other: &Tensor, k: f32) -> Result<()> {
        if self.shape() != other.shape() {
            bail!(
                "add_scaled shape mismatch: {:?} vs {:?}",
                self.shape(),
                other.shape()
            );
        }
        let dst = self.as_f32_mut()?;
        let src = other.as_f32()?;
        vecops::add_scaled(dst, src, k);
        Ok(())
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, k: f32) -> Result<()> {
        vecops::scale(self.as_f32_mut()?, k);
        Ok(())
    }

    /// Mean absolute difference against another tensor (churn metric).
    pub fn mean_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("mean_abs_diff shape mismatch");
        }
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.is_empty() {
            return Ok(0.0);
        }
        Ok((vecops::abs_diff_sum(a, b) / a.len() as f64) as f32)
    }

    /// L2 norm (diagnostics / divergence detection).
    pub fn l2_norm(&self) -> Result<f32> {
        Ok(vecops::sq_sum(self.as_f32()?).sqrt() as f32)
    }

    pub fn is_finite(&self) -> bool {
        match self {
            Tensor::F32 { data, .. } => data.iter().all(|v| v.is_finite()),
            Tensor::I32 { .. } => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctor_checks_numel() {
        assert!(Tensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(&[2], vec![1]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.item_f32().unwrap(), 3.5);
        assert!(t.item_i32().is_err());
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::f32(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        a.add_assign(&b).unwrap();
        a.scale(0.5).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[5.5, 11.0, 16.5]);
    }

    #[test]
    fn add_scaled_is_axpy() {
        let mut a = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::f32(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        a.add_scaled(&b, 0.1).unwrap();
        let got = a.as_f32().unwrap();
        for (g, want) in got.iter().zip([2.0f32, 4.0, 6.0]) {
            assert!((g - want).abs() < 1e-6, "{got:?}");
        }
        let c = Tensor::f32(&[2], vec![0.0; 2]).unwrap();
        assert!(a.add_scaled(&c, 1.0).is_err());
    }

    #[test]
    fn add_assign_shape_mismatch() {
        let mut a = Tensor::f32(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        assert!(a.add_assign(&b).is_err());
    }

    #[test]
    fn mean_abs_diff_basic() {
        let a = Tensor::f32(&[4], vec![0.0, 1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::f32(&[4], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let d = a.mean_abs_diff(&b).unwrap();
        assert!((d - 1.0).abs() < 1e-6);
    }

    #[test]
    fn matches_spec() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![2, 2],
        };
        let t = Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap();
        assert!(t.matches(&spec));
        let t2 = Tensor::i32(&[2, 2], vec![0; 4]).unwrap();
        assert!(!t2.matches(&spec));
    }

    #[test]
    fn zeros_from_spec() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: DType::I32,
            shape: vec![3],
        };
        let t = Tensor::zeros(&spec);
        assert_eq!(t.as_i32().unwrap(), &[0, 0, 0]);
    }

    #[test]
    fn finite_and_norm() {
        let t = Tensor::f32(&[2], vec![3.0, 4.0]).unwrap();
        assert!((t.l2_norm().unwrap() - 5.0).abs() < 1e-6);
        assert!(t.is_finite());
        let bad = Tensor::f32(&[1], vec![f32::NAN]).unwrap();
        assert!(!bad.is_finite());
    }
}

//! Text spec files describing the flattened I/O signature of each artifact.
//!
//! `aot.py` writes one `<name>.spec.txt` next to each `<name>.hlo.txt`.
//! The format is deliberately line-based and dependency-free:
//!
//! ```text
//! spec-version 1
//! name lm_train_step
//! in params.embedding f32 512,32
//! in batch.tokens i32 16,17
//! out loss f32 -
//! ```
//!
//! Dims are comma-separated; `-` denotes a scalar (rank 0). The order of
//! `in`/`out` lines is the exact flattened argument/result order of the
//! lowered jax function, so the Rust side can match tensors positionally
//! while still addressing them by name.

use anyhow::{bail, Context, Result};
use std::fmt;
use std::path::Path;

/// Element type of a tensor crossing the runtime boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unknown dtype in spec: {other:?}"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        };
        write!(f, "{s}")
    }
}

/// Shape + dtype + flattened-position name of one input or output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }
}

/// Parsed signature of one artifact.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form `meta key value` lines (model hyperparameters etc).
    pub meta: Vec<(String, String)>,
}

impl Spec {
    pub fn parse(text: &str) -> Result<Self> {
        let mut spec = Spec::default();
        let mut saw_version = false;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let rest: Vec<&str> = parts.collect();
            let ctx = || format!("spec line {}: {:?}", lineno + 1, raw);
            match tag {
                "spec-version" => {
                    if rest != ["1"] {
                        bail!("unsupported spec version: {rest:?}");
                    }
                    saw_version = true;
                }
                "name" => {
                    spec.name = rest.join(" ");
                }
                "in" | "out" => {
                    if rest.len() != 3 {
                        bail!("expected `{} <name> <dtype> <dims>`, got {}", tag, ctx());
                    }
                    let ts = TensorSpec {
                        name: rest[0].to_string(),
                        dtype: DType::parse(rest[1]).with_context(ctx)?,
                        shape: parse_dims(rest[2]).with_context(ctx)?,
                    };
                    if tag == "in" {
                        spec.inputs.push(ts);
                    } else {
                        spec.outputs.push(ts);
                    }
                }
                "meta" => {
                    if rest.len() < 2 {
                        bail!("expected `meta <key> <value>`, got {}", ctx());
                    }
                    spec.meta.push((rest[0].to_string(), rest[1..].join(" ")));
                }
                other => bail!("unknown spec tag {other:?} in {}", ctx()),
            }
        }
        if !saw_version {
            bail!("spec missing `spec-version 1` header");
        }
        if spec.name.is_empty() {
            bail!("spec missing `name`");
        }
        Ok(spec)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing spec {}", path.display()))
    }

    /// Index of the input with the given name.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    /// Index of the output with the given name.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }

    /// Value of a `meta` key, if present.
    pub fn meta(&self, key: &str) -> Option<&str> {
        self.meta
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Iterate the input specs under a prefix, in flattened order
    /// (allocation-free companion to [`Spec::inputs_with_prefix`]; the flat
    /// plane and zero-initializers walk this).
    pub fn inputs_under<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = &'a TensorSpec> + 'a {
        self.inputs.iter().filter(move |t| t.name.starts_with(prefix))
    }

    /// Inputs whose name starts with `prefix` (e.g. all `params.` leaves),
    /// in flattened order.
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, t)| t.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }

    pub fn outputs_with_prefix(&self, prefix: &str) -> Vec<usize> {
        self.outputs
            .iter()
            .enumerate()
            .filter(|(_, t)| t.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "-" {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|d| {
            d.parse::<usize>()
                .with_context(|| format!("bad dim {d:?}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
spec-version 1
name lm_train_step
meta vocab 512
meta batch 16
in params.embedding f32 512,32
in batch.tokens i32 16,17
in lr f32 -
out loss f32 -
out params.embedding f32 512,32
";

    #[test]
    fn parses_sample() {
        let spec = Spec::parse(SAMPLE).unwrap();
        assert_eq!(spec.name, "lm_train_step");
        assert_eq!(spec.inputs.len(), 3);
        assert_eq!(spec.outputs.len(), 2);
        assert_eq!(spec.inputs[0].shape, vec![512, 32]);
        assert_eq!(spec.inputs[2].shape, Vec::<usize>::new());
        assert_eq!(spec.inputs[1].dtype, DType::I32);
        assert_eq!(spec.meta("vocab"), Some("512"));
        assert_eq!(spec.meta("missing"), None);
    }

    #[test]
    fn indexes_by_name_and_prefix() {
        let spec = Spec::parse(SAMPLE).unwrap();
        assert_eq!(spec.input_index("lr"), Some(2));
        assert_eq!(spec.input_index("nope"), None);
        assert_eq!(spec.output_index("loss"), Some(0));
        assert_eq!(spec.inputs_with_prefix("params."), vec![0]);
        assert_eq!(spec.outputs_with_prefix("params."), vec![1]);
    }

    #[test]
    fn rejects_missing_version() {
        assert!(Spec::parse("name x\n").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = "spec-version 1\nname x\nin a f64 2,2\n";
        assert!(Spec::parse(bad).is_err());
    }

    #[test]
    fn rejects_bad_dims() {
        let bad = "spec-version 1\nname x\nin a f32 2,x\n";
        assert!(Spec::parse(bad).is_err());
    }

    #[test]
    fn tensor_spec_sizes() {
        let t = TensorSpec {
            name: "w".into(),
            dtype: DType::F32,
            shape: vec![3, 4],
        };
        assert_eq!(t.numel(), 12);
        assert_eq!(t.size_bytes(), 48);
        let s = TensorSpec {
            name: "s".into(),
            dtype: DType::F32,
            shape: vec![],
        };
        assert_eq!(s.numel(), 1);
    }
}

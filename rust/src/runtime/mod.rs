//! Runtime layer: loads AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them through PJRT (the `xla` crate).
//!
//! This is the only module that talks to XLA. Everything above it works in
//! terms of host [`Tensor`]s and named [`Executable`]s described by the
//! text spec files that accompany each artifact.
//!
//! Interchange format is HLO **text**, not serialized `HloModuleProto`:
//! jax >= 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).

pub mod bundle;
pub mod client;
pub mod exec;
pub mod flat;
pub mod spec;
pub mod tensor;
pub mod tmap;
pub mod vecops;

pub use bundle::Bundle;
pub use client::Runtime;
pub use exec::Executable;
pub use flat::{FlatBuffer, FlatEntry, FlatLayout};
pub use spec::{DType, Spec, TensorSpec};
pub use tensor::Tensor;
pub use tmap::TensorMap;

//! The flat gradient/parameter plane.
//!
//! Every reduction and checkpoint-exchange hot path used to walk a
//! `TensorMap` entry-by-entry: one hash lookup + one allocation per named
//! tensor per worker per step. A [`FlatLayout`] fixes a deterministic
//! `name -> (offset, len)` ordering once (sorted by name, the same order
//! [`TensorMap::prefix_iter`] yields), and a [`FlatBuffer`] carries all the
//! f32 leaves of one worker/member as a single contiguous `Vec<f32>`:
//!
//! * `sgd::allreduce` sums cache-sized chunks of the fused buffer across
//!   workers on scoped threads — the in-process analogue of
//!   reduce-scatter + all-gather ([`ReduceStrategy::Flat`]).
//! * `codistill::store` publishes checkpoints as `Arc<FlatBuffer>` —
//!   zero-copy in-memory exchange, and serialization writes the plane as
//!   one contiguous byte slice instead of per-tensor framing.
//! * Teacher reloads scatter the plane back into existing tensor storage.
//! * Each window has a stable 64-bit [`content_digest`]; transports
//!   compare digest tables to move only the windows whose bytes changed
//!   since a reader's installed basis (delta checkpoint exchange).
//!
//! Non-f32 leaves (i32 id tables) are rare and stay on the named map path;
//! constructors simply skip them and callers keep them in a residual map.
//!
//! [`ReduceStrategy::Flat`]: crate::sgd::allreduce::ReduceStrategy

use crate::runtime::spec::{DType, Spec};
use crate::runtime::tensor::Tensor;
use crate::runtime::tmap::TensorMap;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::Arc;

/// Fast, stable 64-bit content digest of one window's bytes: FNV-1a over
/// the f32 bit patterns. A pure function of the bits, so a publisher and
/// any reader — in another process, behind a socket, reading a spool file
/// — compute the identical value for identical bytes. Digest equality is
/// the transports' cheap proxy for byte equality: a delta fetch skips
/// every window whose digest matches the reader's installed basis.
///
/// Single-element changes always change the digest (the FNV prime is odd,
/// hence invertible mod 2^64, so a nonzero word difference can never
/// cancel); broader collisions are possible in principle at the usual
/// 2^-64 scale, which is the same trust level as any content-addressed
/// exchange.
pub fn content_digest(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in data {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One named window of the flat plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlatEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

impl FlatEntry {
    pub fn range(&self) -> Range<usize> {
        self.offset..self.offset + self.len
    }

    /// Byte range of this window inside the serialized plane payload
    /// (4 bytes per f32 element) — the unit of a transport's sharded
    /// fetch: a reader `pread`s exactly these bytes out of a `CKPT0002`
    /// payload (or requests them over a socket) instead of the whole
    /// plane.
    pub fn byte_range(&self) -> Range<usize> {
        self.offset * 4..(self.offset + self.len) * 4
    }
}

/// Deterministic name→(offset, len) ordering for the f32 leaves under a
/// prefix. Derived once (from a live map or from a `Spec`), then shared by
/// every buffer, reduction, and checkpoint that speaks the same plane.
#[derive(Debug, Default)]
pub struct FlatLayout {
    entries: Vec<FlatEntry>,
    /// name -> index into `entries` (random access; iteration stays sorted).
    index: HashMap<String, usize>,
    total: usize,
}

impl FlatLayout {
    /// Build from explicit `(name, shape)` windows **in the given order**
    /// (checkpoint deserialization, tests). [`FlatLayout::from_map`] /
    /// [`FlatLayout::from_spec`] are the name-sorted constructors.
    pub fn from_named_shapes(parts: Vec<(String, Vec<usize>)>) -> Self {
        Self::from_parts(parts)
    }

    fn from_parts(parts: Vec<(String, Vec<usize>)>) -> Self {
        let mut entries = Vec::with_capacity(parts.len());
        let mut index = HashMap::with_capacity(parts.len());
        let mut offset = 0usize;
        for (name, shape) in parts {
            let len: usize = shape.iter().product();
            index.insert(name.clone(), entries.len());
            entries.push(FlatEntry {
                name,
                shape,
                offset,
                len,
            });
            offset += len;
        }
        FlatLayout {
            entries,
            index,
            total: offset,
        }
    }

    /// Layout over the f32 entries of `map` under `prefix`, in name order.
    pub fn from_map(map: &TensorMap, prefix: &str) -> Self {
        let parts: Vec<(String, Vec<usize>)> = map
            .prefix_iter(prefix)
            .filter(|(_, t)| t.as_f32().is_ok())
            .map(|(k, t)| (k.to_string(), t.shape().to_vec()))
            .collect();
        Self::from_parts(parts)
    }

    /// Layout over a spec's f32 *inputs* under `prefix` (sorted by name, so
    /// it matches [`FlatLayout::from_map`] of any map feeding that spec).
    pub fn from_spec(spec: &Spec, prefix: &str) -> Self {
        let mut parts: Vec<(String, Vec<usize>)> = spec
            .inputs_under(prefix)
            .filter(|ts| ts.dtype == DType::F32)
            .map(|ts| (ts.name.clone(), ts.shape.clone()))
            .collect();
        parts.sort();
        parts.dedup_by(|a, b| a.0 == b.0);
        Self::from_parts(parts)
    }

    /// Windows in name order.
    pub fn entries(&self) -> &[FlatEntry] {
        &self.entries
    }

    /// Number of named windows.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total f32 elements on the plane.
    pub fn total_len(&self) -> usize {
        self.total
    }

    /// Total plane size in bytes (4 bytes per f32 element).
    pub fn total_bytes(&self) -> usize {
        self.total * 4
    }

    /// Window metadata for a name.
    pub fn entry(&self, name: &str) -> Option<&FlatEntry> {
        self.index.get(name).map(|&i| &self.entries[i])
    }

    /// Index of a named window in plane order — the position digest
    /// tables and delta bases are aligned to.
    pub fn position(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Window names in plane order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }

    /// Element range of one named window (`None` if the plane has no such
    /// window) — range addressing for sharded transports.
    pub fn window_range(&self, name: &str) -> Option<Range<usize>> {
        self.entry(name).map(|e| e.range())
    }

    /// Whether another layout describes the identical plane.
    pub fn same_plane(&self, other: &FlatLayout) -> bool {
        self.entries == other.entries
    }
}

/// One worker's (or one checkpoint's) f32 leaves, fused contiguously
/// according to a shared [`FlatLayout`].
#[derive(Debug, Clone)]
pub struct FlatBuffer {
    layout: Arc<FlatLayout>,
    data: Vec<f32>,
}

impl FlatBuffer {
    /// All-zeros plane.
    pub fn zeros(layout: Arc<FlatLayout>) -> Self {
        let n = layout.total_len();
        FlatBuffer {
            layout,
            data: vec![0.0; n],
        }
    }

    /// Adopt an existing data vector (deserialization, reduce output).
    pub fn from_data(layout: Arc<FlatLayout>, data: Vec<f32>) -> Result<Self> {
        if data.len() != layout.total_len() {
            bail!(
                "flat buffer data has {} elems, layout wants {}",
                data.len(),
                layout.total_len()
            );
        }
        Ok(FlatBuffer { layout, data })
    }

    /// Gather the named tensors of `map` onto the plane (one contiguous
    /// copy per window; errors if a window's tensor is missing or its
    /// shape/dtype disagrees with the layout).
    pub fn gather(layout: Arc<FlatLayout>, map: &TensorMap) -> Result<Self> {
        let mut buf = FlatBuffer {
            data: Vec::with_capacity(layout.total_len()),
            layout,
        };
        for e in buf.layout.entries() {
            let t = map
                .get(&e.name)
                .with_context(|| format!("gathering flat plane window {:?}", e.name))?;
            if t.shape() != e.shape.as_slice() {
                bail!(
                    "flat plane window {:?}: tensor shape {:?} != layout shape {:?}",
                    e.name,
                    t.shape(),
                    e.shape
                );
            }
            buf.data.extend_from_slice(t.as_f32()?);
        }
        debug_assert_eq!(buf.data.len(), buf.layout.total_len());
        Ok(buf)
    }

    /// Re-gather into this buffer's existing allocation.
    pub fn regather(&mut self, map: &TensorMap) -> Result<()> {
        for e in self.layout.entries() {
            let t = map
                .get(&e.name)
                .with_context(|| format!("regathering flat plane window {:?}", e.name))?;
            if t.shape() != e.shape.as_slice() {
                bail!(
                    "flat plane window {:?}: tensor shape {:?} != layout shape {:?}",
                    e.name,
                    t.shape(),
                    e.shape
                );
            }
            self.data[e.range()].copy_from_slice(t.as_f32()?);
        }
        Ok(())
    }

    /// Scatter the plane back into `map`: windows whose destination tensor
    /// already exists with the right shape are overwritten **in place** (no
    /// allocation — the teacher-reload path); missing ones are inserted.
    pub fn scatter_into(&self, map: &mut TensorMap) -> Result<()> {
        // In-place pass over whatever already exists.
        let mut pending: Vec<&FlatEntry> = Vec::new();
        for e in self.layout.entries() {
            match map.get_mut(&e.name) {
                Ok(t) if t.shape() == e.shape.as_slice() && t.as_f32().is_ok() => {
                    t.as_f32_mut()?.copy_from_slice(&self.data[e.range()]);
                }
                _ => pending.push(e),
            }
        }
        for e in pending {
            map.insert(
                e.name.clone(),
                Tensor::f32(&e.shape, self.data[e.range()].to_vec())?,
            );
        }
        Ok(())
    }

    /// Materialize the plane as a fresh named map.
    pub fn to_map(&self) -> Result<TensorMap> {
        let mut m = TensorMap::new();
        self.scatter_into(&mut m)?;
        Ok(m)
    }

    /// Overwrite one named window from a contiguous slice (the receive
    /// side of a sharded fetch: windows arrive independently and are
    /// placed at their layout offsets).
    pub fn write_window(&mut self, name: &str, data: &[f32]) -> Result<()> {
        let e = self
            .layout
            .entry(name)
            .with_context(|| format!("flat plane has no window {name:?}"))?;
        if data.len() != e.len {
            bail!(
                "window {name:?}: got {} elems, layout wants {}",
                data.len(),
                e.len
            );
        }
        self.data[e.range()].copy_from_slice(data);
        Ok(())
    }

    /// Content digest of one named window (see [`content_digest`]).
    pub fn window_digest(&self, name: &str) -> Result<u64> {
        Ok(content_digest(self.view(name)?))
    }

    /// Content digests of every window, in plane order — the digest table
    /// a publisher attaches to a checkpoint and a reader compares a delta
    /// basis against.
    pub fn window_digests(&self) -> Vec<u64> {
        self.layout
            .entries()
            .iter()
            .map(|e| content_digest(&self.data[e.range()]))
            .collect()
    }

    /// The window of one named tensor.
    pub fn view(&self, name: &str) -> Result<&[f32]> {
        let e = self
            .layout
            .entry(name)
            .with_context(|| format!("flat plane has no window {name:?}"))?;
        Ok(&self.data[e.range()])
    }

    pub fn layout(&self) -> &Arc<FlatLayout> {
        &self.layout
    }

    /// The whole contiguous plane.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw vector (serialization).
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ragged_map() -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("grads.w2", Tensor::f32(&[3], vec![4.0, 5.0, 6.0]).unwrap());
        m.insert("grads.b", Tensor::f32(&[1], vec![9.0]).unwrap());
        m.insert("grads.w1", Tensor::f32(&[2, 2], vec![0.0, 1.0, 2.0, 3.0]).unwrap());
        m.insert("grads.ids", Tensor::i32(&[2], vec![7, 8]).unwrap()); // skipped
        m.insert("loss", Tensor::scalar_f32(0.5)); // outside prefix
        m
    }

    #[test]
    fn layout_is_sorted_and_offsets_pack() {
        let m = ragged_map();
        let l = FlatLayout::from_map(&m, "grads.");
        let names: Vec<&str> = l.entries().iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["grads.b", "grads.w1", "grads.w2"]);
        assert_eq!(l.total_len(), 1 + 4 + 3);
        assert_eq!(l.entry("grads.w1").unwrap().offset, 1);
        assert_eq!(l.entry("grads.w2").unwrap().range(), 5..8);
        assert!(l.entry("grads.ids").is_none(), "i32 leaves stay off-plane");
        assert!(l.entry("loss").is_none());
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let m = ragged_map();
        let l = Arc::new(FlatLayout::from_map(&m, "grads."));
        let buf = FlatBuffer::gather(l.clone(), &m).unwrap();
        assert_eq!(buf.data(), &[9.0, 0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(buf.view("grads.w2").unwrap(), &[4.0, 5.0, 6.0]);

        let round = buf.to_map().unwrap();
        for name in ["grads.b", "grads.w1", "grads.w2"] {
            assert_eq!(
                round.get(name).unwrap().as_f32().unwrap(),
                m.get(name).unwrap().as_f32().unwrap(),
                "{name}"
            );
            assert_eq!(round.get(name).unwrap().shape(), m.get(name).unwrap().shape());
        }
    }

    #[test]
    fn scatter_overwrites_in_place() {
        let m = ragged_map();
        let l = Arc::new(FlatLayout::from_map(&m, "grads."));
        let mut buf = FlatBuffer::gather(l, &m).unwrap();
        crate::runtime::vecops::scale(buf.data_mut(), 2.0);

        let mut dst = ragged_map();
        buf.scatter_into(&mut dst).unwrap();
        assert_eq!(dst.get("grads.b").unwrap().as_f32().unwrap(), &[18.0]);
        assert_eq!(
            dst.get("grads.w2").unwrap().as_f32().unwrap(),
            &[8.0, 10.0, 12.0]
        );
        // off-plane entries untouched
        assert_eq!(dst.get("grads.ids").unwrap().as_i32().unwrap(), &[7, 8]);
        assert_eq!(dst.get("loss").unwrap().item_f32().unwrap(), 0.5);
    }

    #[test]
    fn gather_rejects_missing_and_misshapen() {
        let m = ragged_map();
        let l = Arc::new(FlatLayout::from_map(&m, "grads."));
        let mut missing = TensorMap::new();
        missing.insert("grads.b", Tensor::f32(&[1], vec![0.0]).unwrap());
        assert!(FlatBuffer::gather(l.clone(), &missing).is_err());

        let mut misshapen = ragged_map();
        misshapen.insert("grads.b", Tensor::f32(&[2], vec![0.0, 0.0]).unwrap());
        assert!(FlatBuffer::gather(l, &misshapen).is_err());
    }

    #[test]
    fn from_spec_matches_from_map() {
        let spec = Spec::parse(
            "spec-version 1\nname t\n\
             in grads.w1 f32 2,2\nin grads.b f32 1\nin grads.w2 f32 3\n\
             in grads.ids i32 2\nin lr f32 -\n\
             out loss f32 -\n",
        )
        .unwrap();
        let from_spec = FlatLayout::from_spec(&spec, "grads.");
        let from_map = FlatLayout::from_map(&ragged_map(), "grads.");
        assert!(from_spec.same_plane(&from_map));
    }

    #[test]
    fn window_addressing_and_write_window() {
        let m = ragged_map();
        let l = Arc::new(FlatLayout::from_map(&m, "grads."));
        // element + byte ranges line up with the packed offsets
        assert_eq!(l.window_range("grads.w1"), Some(1..5));
        assert_eq!(l.entry("grads.w1").unwrap().byte_range(), 4..20);
        assert_eq!(l.total_bytes(), l.total_len() * 4);
        assert_eq!(
            l.names().collect::<Vec<_>>(),
            vec!["grads.b", "grads.w1", "grads.w2"]
        );
        // assemble a plane window-by-window and match a direct gather
        let full = FlatBuffer::gather(l.clone(), &m).unwrap();
        let mut assembled = FlatBuffer::zeros(l.clone());
        for name in ["grads.w2", "grads.b", "grads.w1"] {
            assembled
                .write_window(name, full.view(name).unwrap())
                .unwrap();
        }
        assert_eq!(assembled.data(), full.data());
        // wrong length and unknown window are rejected
        assert!(assembled.write_window("grads.b", &[1.0, 2.0]).is_err());
        assert!(assembled.write_window("grads.nope", &[1.0]).is_err());
    }

    #[test]
    fn window_digests_track_content_not_position() {
        let m = ragged_map();
        let l = Arc::new(FlatLayout::from_map(&m, "grads."));
        let buf = FlatBuffer::gather(l.clone(), &m).unwrap();
        let digests = buf.window_digests();
        assert_eq!(digests.len(), l.len());
        // plane-order alignment with position()
        for (i, name) in ["grads.b", "grads.w1", "grads.w2"].iter().enumerate() {
            assert_eq!(l.position(name), Some(i));
            assert_eq!(buf.window_digest(name).unwrap(), digests[i]);
        }
        assert_eq!(l.position("grads.nope"), None);
        // identical bytes => identical digest, across distinct buffers
        let again = FlatBuffer::gather(l.clone(), &m).unwrap();
        assert_eq!(again.window_digests(), digests);
        // a one-element change flips exactly that window's digest
        let mut changed = buf.clone();
        changed.data_mut()[l.entry("grads.w1").unwrap().offset] += 1.0;
        let changed_digests = changed.window_digests();
        assert_eq!(changed_digests[0], digests[0]);
        assert_ne!(changed_digests[1], digests[1]);
        assert_eq!(changed_digests[2], digests[2]);
        // the digest is a function of bytes, not shape metadata
        assert_eq!(content_digest(&[]), content_digest(&[]));
        assert_ne!(content_digest(&[0.0]), content_digest(&[0.0, 0.0]));
        // 0.0 and -0.0 are different bytes, so different digests
        assert_ne!(content_digest(&[0.0]), content_digest(&[-0.0]));
    }

    #[test]
    fn zeros_and_regather() {
        let m = ragged_map();
        let l = Arc::new(FlatLayout::from_map(&m, "grads."));
        let mut buf = FlatBuffer::zeros(l);
        assert!(buf.data().iter().all(|&v| v == 0.0));
        buf.regather(&m).unwrap();
        assert_eq!(buf.view("grads.b").unwrap(), &[9.0]);
        assert!(FlatBuffer::from_data(buf.layout().clone(), vec![0.0; 3]).is_err());
    }
}

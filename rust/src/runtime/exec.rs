//! A single compiled artifact: HLO text + spec, executed via PJRT.

use crate::runtime::spec::Spec;
use crate::runtime::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Compiled executable plus its flattened I/O spec.
///
/// All artifacts are lowered with `return_tuple=True`, so execution yields a
/// single tuple literal which is decomposed back into the spec'd outputs.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: Spec,
    /// Total number of `run` invocations (perf accounting).
    runs: AtomicU64,
}

impl Executable {
    /// Load HLO text + spec and compile on the given client.
    pub fn load(client: &xla::PjRtClient, hlo_path: &Path, spec_path: &Path) -> Result<Self> {
        let spec = Spec::load(spec_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .with_context(|| format!("non-utf8 path {}", hlo_path.display()))?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", hlo_path.display()))?;
        Ok(Executable {
            exe,
            spec,
            runs: AtomicU64::new(0),
        })
    }

    pub fn spec(&self) -> &Spec {
        &self.spec
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn run_count(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    /// Execute with host tensors, validating shapes/dtypes against the spec.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, ts)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            if !t.matches(ts) {
                bail!(
                    "{}: input {} ({}) mismatch: tensor {:?} {:?} vs spec {:?} {:?}",
                    self.spec.name,
                    i,
                    ts.name,
                    t.dtype(),
                    t.shape(),
                    ts.dtype,
                    ts.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (no spec validation on inputs).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        self.runs.fetch_add(1, Ordering::Relaxed);
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        self.collect_outputs(result)
    }

    /// Execute with borrowed literals — lets callers keep converted
    /// literals for step-invariant inputs (§Perf: constant-input caching).
    pub fn run_refs(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        self.runs.fetch_add(1, Ordering::Relaxed);
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        self.collect_outputs(result)
    }

    fn collect_outputs(
        &self,
        result: Vec<Vec<xla::PjRtBuffer>>,
    ) -> Result<Vec<Tensor>> {
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple
            .to_tuple()
            .with_context(|| format!("{}: decomposing result tuple", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: spec declares {} outputs but executable returned {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ts) in parts.iter().zip(self.spec.outputs.iter()) {
            let t = Tensor::from_literal(lit)
                .with_context(|| format!("{}: converting output {}", self.spec.name, ts.name))?;
            if !t.matches(ts) {
                bail!(
                    "{}: output {} mismatch: got {:?} {:?}, spec {:?} {:?}",
                    self.spec.name,
                    ts.name,
                    t.dtype(),
                    t.shape(),
                    ts.dtype,
                    ts.shape
                );
            }
            out.push(t);
        }
        Ok(out)
    }
}

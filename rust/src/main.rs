fn main() -> anyhow::Result<()> {
    codistill::cli::main_entry()
}

//! Worker fan-out: run one closure per worker on its own thread and
//! collect results in worker order. PJRT executions are internally
//! synchronized, so workers sharing a compiled executable is safe; this
//! is the in-process analogue of the paper's per-GPU workers.

use anyhow::Result;

/// Run `f(worker_id)` for `n` workers concurrently; results in id order.
pub fn parallel_workers<T, F>(n: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if n == 0 {
        return Ok(Vec::new());
    }
    let f = &f;
    let results: Vec<Result<T>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n).map(|i| scope.spawn(move || f(i))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    results.into_iter().collect()
}

/// Sequential variant (ablation/debug; same signature).
pub fn sequential_workers<T, F>(n: usize, f: F) -> Result<Vec<T>>
where
    F: Fn(usize) -> Result<T>,
{
    (0..n).map(f).collect()
}

/// Chunk fan-out over one contiguous f32 plane: split `out` into at most
/// `available_parallelism` contiguous chunks of at least `min_chunk`
/// elements and run `f(plane_offset, chunk)` for each on its own scoped
/// thread. The flat allreduce drives this with a cache-sized `min_chunk`
/// so each chunk stays resident while it is summed across all workers.
pub fn parallel_chunks<F>(out: &mut [f32], min_chunk: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let total = out.len();
    if total == 0 {
        return;
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let chunks = threads.min(total.div_ceil(min_chunk.max(1))).max(1);
    let chunk_len = total.div_ceil(chunks);
    if chunks == 1 {
        f(0, out);
        return;
    }
    let f = &f;
    std::thread::scope(|scope| {
        for (ci, oc) in out.chunks_mut(chunk_len).enumerate() {
            scope.spawn(move || f(ci * chunk_len, oc));
        }
    });
}

/// Re-export site for the group step used by models::lm::LmSyncGroup.
pub struct SyncGroup;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_worker_order() {
        let out = parallel_workers(8, |i| Ok(i * 10)).unwrap();
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn all_workers_run() {
        let count = AtomicUsize::new(0);
        parallel_workers(16, |_| {
            count.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn error_propagates() {
        let r = parallel_workers(4, |i| {
            if i == 2 {
                anyhow::bail!("worker {i} failed")
            } else {
                Ok(i)
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn zero_workers_ok() {
        let out: Vec<usize> = parallel_workers(0, |i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn matches_sequential() {
        let a = parallel_workers(5, |i| Ok(i * i)).unwrap();
        let b = sequential_workers(5, |i| Ok(i * i)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_chunks_covers_plane_once() {
        for n in [0usize, 1, 5, 64, 1000] {
            let mut out = vec![0.0f32; n];
            parallel_chunks(&mut out, 16, |start, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v += (start + i) as f32; // += catches double-visits
                }
            });
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as f32, "n={n} i={i}");
            }
        }
    }
}

//! Synchronous-SGD machinery: data-parallel worker groups and gradient
//! reduction.
//!
//! A [`SyncGroup`](group::SyncGroup) runs W workers, each computing
//! gradients on its own shard via the per-worker `grad` executable, reduces
//! them ([`allreduce`]), and applies the update via the `apply` executable.
//! This is the real algorithmic path of distributed sync SGD; the *wires*
//! are priced by [`crate::netsim`] (DESIGN.md §4).
//!
//! The fused path (`train_step` at effective batch = W·b) is mathematically
//! identical — `group::tests` asserts the equivalence numerically — and is
//! what the large experiment sweeps use for speed.

pub mod allreduce;
pub mod group;

pub use allreduce::{allreduce_mean, allreduce_mean_flat, ReduceStrategy};
pub use group::SyncGroup;

//! Gradient reduction across workers.
//!
//! Two strategies with identical semantics (mean over workers, leaf-wise):
//!
//! * [`ReduceStrategy::Naive`]: sequential accumulation — O(W·N) adds on
//!   one thread.
//! * [`ReduceStrategy::Tree`]: pairwise tree reduction across threads —
//!   the in-process analogue of a reduction tree, and measurably faster
//!   for large W·N (see `benches/perf_hotpath.rs`).

use crate::runtime::TensorMap;
#[cfg(test)]
use crate::runtime::Tensor;
use anyhow::{bail, Result};

/// Reduction algorithm choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceStrategy {
    Naive,
    Tree,
}

/// Mean-reduce the `grads.`-prefixed entries of per-worker maps into one
/// map (same names). All maps must share identical shapes.
pub fn allreduce_mean(
    workers: Vec<TensorMap>,
    prefix: &str,
    strategy: ReduceStrategy,
) -> Result<TensorMap> {
    if workers.is_empty() {
        bail!("allreduce over zero workers");
    }
    let n = workers.len();
    let mut acc = match strategy {
        ReduceStrategy::Naive => reduce_naive(workers, prefix)?,
        ReduceStrategy::Tree => reduce_tree(workers, prefix)?,
    };
    let names: Vec<String> = acc
        .prefix_entries(prefix)
        .iter()
        .map(|(k, _)| k.to_string())
        .collect();
    for name in names {
        acc.get_mut(&name)?.scale(1.0 / n as f32)?;
    }
    Ok(acc)
}

fn sum_into(dst: &mut TensorMap, src: &TensorMap, prefix: &str) -> Result<()> {
    let names: Vec<String> = dst
        .prefix_entries(prefix)
        .iter()
        .map(|(k, _)| k.to_string())
        .collect();
    if names.is_empty() {
        bail!("no entries under {prefix:?} to reduce");
    }
    for name in names {
        let s = src.get(&name)?.clone();
        dst.get_mut(&name)?.add_assign(&s)?;
    }
    Ok(())
}

fn reduce_naive(mut workers: Vec<TensorMap>, prefix: &str) -> Result<TensorMap> {
    let mut acc = workers.remove(0);
    // Touch the prefix once to validate presence even for W=1.
    if acc.prefix_entries(prefix).is_empty() {
        bail!("no entries under {prefix:?} to reduce");
    }
    for w in &workers {
        sum_into(&mut acc, w, prefix)?;
    }
    Ok(acc)
}

fn reduce_tree(mut workers: Vec<TensorMap>, prefix: &str) -> Result<TensorMap> {
    if workers.iter().any(|w| w.prefix_entries(prefix).is_empty()) {
        bail!("no entries under {prefix:?} to reduce");
    }
    while workers.len() > 1 {
        let mut next: Vec<TensorMap> = Vec::with_capacity(workers.len().div_ceil(2));
        let mut pairs: Vec<(TensorMap, Option<TensorMap>)> = Vec::new();
        while workers.len() >= 2 {
            let b = workers.pop().unwrap();
            let a = workers.pop().unwrap();
            pairs.push((a, Some(b)));
        }
        if let Some(last) = workers.pop() {
            pairs.push((last, None));
        }
        // Pairwise sums in parallel.
        let results: Vec<Result<TensorMap>> = std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .into_iter()
                .map(|(mut a, b)| {
                    scope.spawn(move || {
                        if let Some(b) = b {
                            sum_into(&mut a, &b, prefix)?;
                        }
                        Ok(a)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in results {
            next.push(r?);
        }
        workers = next;
    }
    Ok(workers.pop().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(vals: &[f32]) -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("grads.w", Tensor::f32(&[vals.len()], vals.to_vec()).unwrap());
        m.insert("loss", Tensor::scalar_f32(1.0));
        m
    }

    #[test]
    fn naive_mean_of_three() {
        let ws = vec![worker(&[1.0, 2.0]), worker(&[3.0, 4.0]), worker(&[5.0, 6.0])];
        let r = allreduce_mean(ws, "grads.", ReduceStrategy::Naive).unwrap();
        assert_eq!(r.get("grads.w").unwrap().as_f32().unwrap(), &[3.0, 4.0]);
    }

    #[test]
    fn tree_matches_naive() {
        for n in [1usize, 2, 3, 5, 8, 13] {
            let ws_a: Vec<TensorMap> =
                (0..n).map(|i| worker(&[i as f32, 2.0 * i as f32])).collect();
            let ws_b = ws_a.clone();
            let a = allreduce_mean(ws_a, "grads.", ReduceStrategy::Naive).unwrap();
            let b = allreduce_mean(ws_b, "grads.", ReduceStrategy::Tree).unwrap();
            let va = a.get("grads.w").unwrap().as_f32().unwrap();
            let vb = b.get("grads.w").unwrap().as_f32().unwrap();
            for (x, y) in va.iter().zip(vb.iter()) {
                assert!((x - y).abs() < 1e-5, "n={n}: {va:?} vs {vb:?}");
            }
        }
    }

    #[test]
    fn empty_workers_error() {
        assert!(allreduce_mean(vec![], "grads.", ReduceStrategy::Naive).is_err());
    }

    #[test]
    fn missing_prefix_errors() {
        let ws = vec![worker(&[1.0])];
        assert!(allreduce_mean(ws, "nope.", ReduceStrategy::Naive).is_err());
    }

    #[test]
    fn single_worker_identity() {
        let r = allreduce_mean(vec![worker(&[7.0, 9.0])], "grads.", ReduceStrategy::Tree)
            .unwrap();
        assert_eq!(r.get("grads.w").unwrap().as_f32().unwrap(), &[7.0, 9.0]);
    }
}

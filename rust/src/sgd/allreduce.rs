//! Gradient reduction across workers.
//!
//! Three strategies with identical semantics (mean over workers, leaf-wise):
//!
//! * [`ReduceStrategy::Naive`]: sequential accumulation — O(W·N) adds on
//!   one thread.
//! * [`ReduceStrategy::Tree`]: pairwise tree reduction across threads —
//!   the in-process analogue of a reduction tree. Still pays one named map
//!   per round and touches every element log₂(W) times.
//! * [`ReduceStrategy::Flat`]: the fused bucketed reduce. Workers gather
//!   onto one contiguous plane ([`FlatBuffer`]), the plane is split into
//!   cache-sized chunks, and each chunk is summed across *all* workers on
//!   its own thread with the `1/W` scale folded into the same pass — the
//!   in-process analogue of reduce-scatter + all-gather. No per-tensor
//!   clones, no per-name hashing, and every element is written exactly
//!   once. This is the default for `LmSyncGroup` and the substrate the
//!   cross-process exchange will reuse (see ROADMAP).
//!
//! `benches/perf_hotpath.rs` measures all three at LM-gradient sizes.

use crate::runtime::flat::{FlatBuffer, FlatLayout};
use crate::runtime::vecops;
use crate::runtime::TensorMap;
use crate::sgd::group::parallel_chunks;
#[cfg(test)]
use crate::runtime::Tensor;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Reduction algorithm choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReduceStrategy {
    Naive,
    Tree,
    #[default]
    Flat,
}

impl ReduceStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "naive" => ReduceStrategy::Naive,
            "tree" => ReduceStrategy::Tree,
            "flat" => ReduceStrategy::Flat,
            other => bail!("unknown reduce strategy {other:?} (naive|tree|flat)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReduceStrategy::Naive => "naive",
            ReduceStrategy::Tree => "tree",
            ReduceStrategy::Flat => "flat",
        }
    }
}

/// Mean-reduce the `grads.`-prefixed entries of per-worker maps into one
/// map (same names; worker 0's off-prefix entries ride along, as with the
/// sequential strategies). All maps must share identical shapes.
pub fn allreduce_mean(
    workers: Vec<TensorMap>,
    prefix: &str,
    strategy: ReduceStrategy,
) -> Result<TensorMap> {
    if workers.is_empty() {
        bail!("allreduce over zero workers");
    }
    let n = workers.len();
    match strategy {
        // Flat folds the 1/n scale into the chunk pass itself.
        ReduceStrategy::Flat => reduce_flat(workers, prefix),
        ReduceStrategy::Naive | ReduceStrategy::Tree => {
            let mut acc = match strategy {
                ReduceStrategy::Naive => reduce_naive(workers, prefix)?,
                _ => reduce_tree(workers, prefix)?,
            };
            let inv = 1.0 / n as f32;
            for (_, t) in acc.prefix_iter_mut(prefix) {
                t.scale(inv)?;
            }
            Ok(acc)
        }
    }
}

/// `dst[prefix] += src[prefix]`, leaf-wise, borrowing the source tensors
/// (no clone-per-add on the hot loop).
fn sum_into(dst: &mut TensorMap, src: &TensorMap, prefix: &str) -> Result<()> {
    let mut touched = 0usize;
    for (name, d) in dst.prefix_iter_mut(prefix) {
        d.add_assign(src.get(name)?)?;
        touched += 1;
    }
    if touched == 0 {
        bail!("no entries under {prefix:?} to reduce");
    }
    Ok(())
}

fn reduce_naive(mut workers: Vec<TensorMap>, prefix: &str) -> Result<TensorMap> {
    let mut acc = workers.remove(0);
    // Touch the prefix once to validate presence even for W=1.
    if acc.prefix_iter(prefix).next().is_none() {
        bail!("no entries under {prefix:?} to reduce");
    }
    for w in &workers {
        sum_into(&mut acc, w, prefix)?;
    }
    Ok(acc)
}

fn reduce_tree(mut workers: Vec<TensorMap>, prefix: &str) -> Result<TensorMap> {
    if workers.iter().any(|w| w.prefix_iter(prefix).next().is_none()) {
        bail!("no entries under {prefix:?} to reduce");
    }
    while workers.len() > 1 {
        let mut next: Vec<TensorMap> = Vec::with_capacity(workers.len().div_ceil(2));
        let mut pairs: Vec<(TensorMap, Option<TensorMap>)> = Vec::new();
        while workers.len() >= 2 {
            let b = workers.pop().unwrap();
            let a = workers.pop().unwrap();
            pairs.push((a, Some(b)));
        }
        if let Some(last) = workers.pop() {
            pairs.push((last, None));
        }
        // Pairwise sums in parallel.
        let results: Vec<Result<TensorMap>> = std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .into_iter()
                .map(|(mut a, b)| {
                    scope.spawn(move || {
                        if let Some(b) = b {
                            sum_into(&mut a, &b, prefix)?;
                        }
                        Ok(a)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in results {
            next.push(r?);
        }
        workers = next;
    }
    Ok(workers.pop().unwrap())
}

/// The fused bucketed reduce: derive the plane from worker 0, validate,
/// and delegate to [`allreduce_mean_flat`].
fn reduce_flat(workers: Vec<TensorMap>, prefix: &str) -> Result<TensorMap> {
    // Semantics parity with Naive/Tree: a non-f32 leaf under the prefix is
    // an error, not a silently unreduced pass-through.
    for (name, t) in workers[0].prefix_iter(prefix) {
        if t.as_f32().is_err() {
            bail!("cannot reduce non-f32 tensor {name:?} under {prefix:?}");
        }
    }
    let layout = Arc::new(FlatLayout::from_map(&workers[0], prefix));
    if layout.is_empty() {
        bail!("no entries under {prefix:?} to reduce");
    }
    allreduce_mean_flat(workers, layout)
}

/// Flat mean-reduce against a caller-cached layout — the steady-state hot
/// path: `LmSyncGroup` derives the plane once and reuses it every step, so
/// a training step performs no name hashing or layout allocation at all.
/// Leaves outside the layout are ignored; derive the layout with
/// [`FlatLayout::from_map`]/[`FlatLayout::from_spec`] and validate once.
pub fn allreduce_mean_flat(
    workers: Vec<TensorMap>,
    layout: Arc<FlatLayout>,
) -> Result<TensorMap> {
    if workers.is_empty() {
        bail!("allreduce over zero workers");
    }
    if layout.is_empty() {
        bail!("flat allreduce over an empty layout");
    }
    let n = workers.len();
    // Fuse each worker's leaves into one contiguous buffer (a single
    // sequential copy per worker — the in-process stand-in for the
    // transport placing remote gradients into a registered flat region).
    let planes: Vec<FlatBuffer> = workers
        .iter()
        .map(|w| FlatBuffer::gather(layout.clone(), w))
        .collect::<Result<_>>()?;

    let mut out = vec![0.0f32; layout.total_len()];
    {
        let views: Vec<&[f32]> = planes.iter().map(|p| p.data()).collect();
        let views = views.as_slice();
        let inv = 1.0 / n as f32;
        parallel_chunks(&mut out, vecops::PAR_CHUNK, |start, chunk| {
            vecops::mean_reduce_chunk(chunk, views, start, inv);
        });
    }

    // Scatter the reduced plane into worker 0's map so off-prefix entries
    // (losses, counters) ride along exactly like the sequential paths.
    let mut base = workers.into_iter().next().unwrap();
    FlatBuffer::from_data(layout, out)?.scatter_into(&mut base)?;
    Ok(base)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(vals: &[f32]) -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("grads.w", Tensor::f32(&[vals.len()], vals.to_vec()).unwrap());
        m.insert("loss", Tensor::scalar_f32(1.0));
        m
    }

    #[test]
    fn naive_mean_of_three() {
        let ws = vec![worker(&[1.0, 2.0]), worker(&[3.0, 4.0]), worker(&[5.0, 6.0])];
        let r = allreduce_mean(ws, "grads.", ReduceStrategy::Naive).unwrap();
        assert_eq!(r.get("grads.w").unwrap().as_f32().unwrap(), &[3.0, 4.0]);
    }

    #[test]
    fn flat_mean_of_three_keeps_off_prefix_entries() {
        let ws = vec![worker(&[1.0, 2.0]), worker(&[3.0, 4.0]), worker(&[5.0, 6.0])];
        let r = allreduce_mean(ws, "grads.", ReduceStrategy::Flat).unwrap();
        assert_eq!(r.get("grads.w").unwrap().as_f32().unwrap(), &[3.0, 4.0]);
        assert_eq!(r.get("loss").unwrap().item_f32().unwrap(), 1.0);
    }

    #[test]
    fn strategies_agree() {
        for n in [1usize, 2, 3, 5, 8, 13] {
            let make = || -> Vec<TensorMap> {
                (0..n).map(|i| worker(&[i as f32, 2.0 * i as f32])).collect()
            };
            let a = allreduce_mean(make(), "grads.", ReduceStrategy::Naive).unwrap();
            let b = allreduce_mean(make(), "grads.", ReduceStrategy::Tree).unwrap();
            let c = allreduce_mean(make(), "grads.", ReduceStrategy::Flat).unwrap();
            let va = a.get("grads.w").unwrap().as_f32().unwrap();
            let vb = b.get("grads.w").unwrap().as_f32().unwrap();
            let vc = c.get("grads.w").unwrap().as_f32().unwrap();
            for ((x, y), z) in va.iter().zip(vb.iter()).zip(vc.iter()) {
                assert!((x - y).abs() < 1e-5, "n={n}: {va:?} vs {vb:?}");
                assert!((x - z).abs() < 1e-5, "n={n}: {va:?} vs {vc:?}");
            }
        }
    }

    #[test]
    fn empty_workers_error() {
        assert!(allreduce_mean(vec![], "grads.", ReduceStrategy::Naive).is_err());
        assert!(allreduce_mean(vec![], "grads.", ReduceStrategy::Flat).is_err());
    }

    #[test]
    fn missing_prefix_errors() {
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Flat] {
            let ws = vec![worker(&[1.0])];
            assert!(allreduce_mean(ws, "nope.", s).is_err(), "{s:?}");
        }
    }

    #[test]
    fn non_f32_under_prefix_errors_in_every_strategy() {
        let mut w0 = worker(&[1.0, 2.0]);
        w0.insert("grads.count", Tensor::i32(&[1], vec![3]).unwrap());
        let mut w1 = worker(&[3.0, 4.0]);
        w1.insert("grads.count", Tensor::i32(&[1], vec![4]).unwrap());
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Flat] {
            let r = allreduce_mean(vec![w0.clone(), w1.clone()], "grads.", s);
            assert!(r.is_err(), "{s:?} silently accepted an i32 grad leaf");
        }
    }

    #[test]
    fn cached_layout_path_matches_checked_path() {
        let ws = vec![worker(&[1.0, 2.0]), worker(&[3.0, 4.0])];
        let layout = Arc::new(FlatLayout::from_map(&ws[0], "grads."));
        let a = allreduce_mean_flat(ws.clone(), layout).unwrap();
        let b = allreduce_mean(ws, "grads.", ReduceStrategy::Flat).unwrap();
        assert_eq!(
            a.get("grads.w").unwrap().as_f32().unwrap(),
            b.get("grads.w").unwrap().as_f32().unwrap()
        );
        assert!(allreduce_mean_flat(vec![], Arc::new(FlatLayout::default())).is_err());
    }

    #[test]
    fn ragged_worker_errors_not_panics() {
        // Second worker missing a leaf the layout expects.
        let mut short = TensorMap::new();
        short.insert("grads.other", Tensor::f32(&[2], vec![0.0; 2]).unwrap());
        let ws = vec![worker(&[1.0, 2.0]), short];
        assert!(allreduce_mean(ws, "grads.", ReduceStrategy::Flat).is_err());
    }

    #[test]
    fn single_worker_identity() {
        for s in [ReduceStrategy::Tree, ReduceStrategy::Flat] {
            let r = allreduce_mean(vec![worker(&[7.0, 9.0])], "grads.", s).unwrap();
            assert_eq!(r.get("grads.w").unwrap().as_f32().unwrap(), &[7.0, 9.0], "{s:?}");
        }
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for s in [ReduceStrategy::Naive, ReduceStrategy::Tree, ReduceStrategy::Flat] {
            assert_eq!(ReduceStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(ReduceStrategy::parse("ring").is_err());
        assert_eq!(ReduceStrategy::default(), ReduceStrategy::Flat);
    }
}

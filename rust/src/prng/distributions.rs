//! Discrete distributions used by the data synthesizers.

use crate::prng::Pcg64;

/// Zipf-distributed ranks over `{0, .., n-1}` with exponent `s`, sampled by
/// inverse-CDF over a precomputed table. Natural-language token frequencies
/// are approximately Zipfian, which is what makes the synthetic corpus's
/// unigram statistics (and the unigram label-smoothing baseline of Fig 2a)
/// behave like the paper's word-piece distribution.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Probability mass of rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.uniform();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// General categorical distribution from (possibly unnormalized) weights.
#[derive(Debug, Clone)]
pub struct Categorical {
    cdf: Vec<f64>,
}

impl Categorical {
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0, "negative categorical weight");
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "all-zero categorical weights");
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        Categorical { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.uniform();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_rank0_most_frequent() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Pcg64::new(1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn zipf_pmf_sums_to_one() {
        let z = Zipf::new(50, 1.0);
        let total: f64 = (0..50).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn categorical_respects_weights() {
        let c = Categorical::new(&[1.0, 0.0, 3.0]);
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[c.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    #[should_panic]
    fn categorical_rejects_all_zero() {
        Categorical::new(&[0.0, 0.0]);
    }
}

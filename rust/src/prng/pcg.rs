//! PCG-XSH-RR 64/32 (O'Neill 2014) and SplitMix64 (Steele et al. 2014).
//!
//! PCG is the workhorse stream generator; SplitMix64 seeds it and derives
//! child seeds. Both are tiny, fast, and well-characterized — plenty for
//! simulation workloads (no cryptographic claims).

/// SplitMix64: one 64-bit state, full-avalanche output. Used for seeding.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit rotated-xorshift output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Construct from a seed; the stream id is derived from the seed so two
    /// generators with different seeds are on different sequences.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::with_stream(sm.next_u64(), sm.next_u64())
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut pcg = Pcg64 { state: 0, inc };
        pcg.next_u32();
        pcg.state = pcg.state.wrapping_add(seed);
        pcg.next_u32();
        pcg
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)). Used for straggler tails (netsim).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        let mut c = Pcg64::new(8);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut rng = Pcg64::new(123);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(99);
        let n = 40_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            assert!(rng.lognormal(0.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::new(11);
        let hits = (0..10_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(2);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(xs, (0..50).collect::<Vec<u32>>());
    }
}

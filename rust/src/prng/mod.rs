//! Deterministic PRNG substrate.
//!
//! The offline vendor set ships no `rand` crate, so the coordinator owns its
//! own generators. Everything downstream (data synthesis, shard assignment,
//! straggler sampling, churn retrain seeds) derives from [`Pcg64`] streams
//! split off a root seed via [`SplitMix64`], so every experiment is exactly
//! reproducible from one `u64`.

mod distributions;
mod pcg;

pub use distributions::{Categorical, Zipf};
pub use pcg::{Pcg64, SplitMix64};

/// Derive a child seed for a named subsystem. Stable across runs: the name
/// is hashed (FNV-1a) together with the parent seed, so adding subsystems
/// never perturbs existing streams.
pub fn derive_seed(parent: u64, name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325 ^ parent.rotate_left(17);
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    // Finalize through SplitMix64 for avalanche.
    SplitMix64::new(h).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_stable_and_distinct() {
        let a = derive_seed(42, "corpus");
        let b = derive_seed(42, "corpus");
        let c = derive_seed(42, "straggler");
        let d = derive_seed(43, "corpus");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }
}

//! Simulated cluster wall-clock model.
//!
//! The paper's Fig 1b shape — step time degrading as synchronous worker
//! count grows — comes from (i) allreduce cost scaling with workers and
//! model size and (ii) the max-over-workers straggler tail (§1: "the long
//! tail of the machine and network latency distributions"). Neither is
//! observable on a single host, so this module prices them analytically:
//!
//!   step_time = max_{w∈workers}(compute_w) + allreduce_time
//!   compute_w ~ compute_mean · LogNormal(0, σ)
//!   allreduce_time = 2·(W−1)/W · bytes/bandwidth + 2·(W−1)·latency
//!
//! (ring allreduce; bandwidth term ~flat in W, latency term linear in W).
//! Codistillation's exchange prices a checkpoint write + read per reload
//! interval — the communication-cost asymmetry at the heart of §2.1.
//!
//! Defaults are calibrated to the paper's testbed scale: ~100ms/step GPU
//! compute, 10GbE-ish effective bandwidth, sub-millisecond base latency.

use crate::prng::Pcg64;

pub mod calibrate;
pub mod sweep;

pub use calibrate::{calibrate, Calibration};

/// Analytic wall-clock model for one synchronous worker group.
#[derive(Debug, Clone)]
pub struct ClusterModel {
    /// Synchronous workers in the group.
    pub workers: usize,
    /// Mean per-worker compute time per step (seconds).
    pub compute_mean_s: f64,
    /// Lognormal sigma of per-worker compute jitter (straggler tail).
    pub straggler_sigma: f64,
    /// Gradient/model bytes exchanged per step per worker.
    pub model_bytes: u64,
    /// Effective point-to-point bandwidth (bytes/second).
    pub bandwidth_bps: f64,
    /// Per-hop latency (seconds).
    pub latency_s: f64,
    /// Steps between checkpoint exchanges (codistillation only).
    pub reload_interval: u64,
}

impl ClusterModel {
    /// A paper-scale default: `workers` GPUs, 40 MB model (the scaled LM's
    /// f32 params × a gradient exchange), 1.25 GB/s effective bandwidth.
    pub fn gpu_cluster(workers: usize, model_bytes: u64) -> Self {
        ClusterModel {
            workers,
            compute_mean_s: 0.1,
            straggler_sigma: 0.15,
            model_bytes,
            bandwidth_bps: 1.25e9,
            latency_s: 25e-6,
            reload_interval: 50,
        }
    }

    /// Ring-allreduce time for this group.
    pub fn allreduce_time(&self) -> f64 {
        if self.workers <= 1 {
            return 0.0;
        }
        let w = self.workers as f64;
        let bw_term = 2.0 * (w - 1.0) / w * self.model_bytes as f64 / self.bandwidth_bps;
        let lat_term = 2.0 * (w - 1.0) * self.latency_s;
        bw_term + lat_term
    }

    /// Max-over-workers compute time (the synchronous straggler effect).
    pub fn compute_time(&self, rng: &mut Pcg64) -> f64 {
        let mut worst: f64 = 0.0;
        for _ in 0..self.workers.max(1) {
            let t = self.compute_mean_s * rng.lognormal(0.0, self.straggler_sigma);
            worst = worst.max(t);
        }
        worst
    }

    /// One synchronous step's wall time.
    pub fn step_time(&self, rng: &mut Pcg64) -> f64 {
        self.compute_time(rng) + self.allreduce_time()
    }

    /// Expected step time (deterministic; used for closed-form sweeps).
    /// E[max of n lognormals] is approximated by sampling.
    pub fn mean_step_time(&self, samples: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::new(seed);
        let total: f64 = (0..samples).map(|_| self.step_time(&mut rng)).sum();
        total / samples as f64
    }

    /// Wall cost of one codistillation checkpoint exchange: write the
    /// params once + read each teacher's params once, at full bandwidth.
    /// Amortized per exchange (NOT per step) — this is why codistillation's
    /// communication is cheap (§2.1).
    pub fn checkpoint_exchange_time(&self) -> f64 {
        self.full_exchange_time(1)
    }

    /// Full-plane exchange: one checkpoint write plus `teachers`
    /// whole-plane reads (each reader pulls every byte of the plane —
    /// the `latest` path of every transport).
    pub fn full_exchange_time(&self, teachers: usize) -> f64 {
        (1 + teachers) as f64 * self.model_bytes as f64 / self.bandwidth_bps
    }

    /// Sharded exchange: one checkpoint write plus `teachers` windowed
    /// reads that move only `bytes_fetched` each (`fetch_windows` /
    /// `SocketTransport::with_windowed_fetch` — `bytes_fetched /
    /// bandwidth` per reader instead of the whole plane).
    pub fn sharded_exchange_time(&self, teachers: usize, bytes_fetched: u64) -> f64 {
        (self.model_bytes as f64 + teachers as f64 * bytes_fetched as f64) / self.bandwidth_bps
    }

    /// Incremental (delta) exchange: one full checkpoint write plus
    /// `teachers` delta reads, each moving only the `changed_fraction` of
    /// the plane whose window digests differ from the reader's installed
    /// basis (`ExchangeTransport::fetch` with a `Basis`). At
    /// `changed_fraction = 1.0` this equals the full exchange; in the
    /// steady state of a converging run the fraction — and with it the
    /// read cost — collapses toward the digest-table overhead, which is
    /// below this model's resolution.
    pub fn delta_exchange_time(&self, teachers: usize, changed_fraction: f64) -> f64 {
        let f = changed_fraction.clamp(0.0, 1.0);
        self.sharded_exchange_time(teachers, (f * self.model_bytes as f64) as u64)
    }

    /// Compressed incremental exchange: the delta exchange with every
    /// *read* byte lossless-encoded at `ratio` (encoded bytes / raw
    /// bytes): each of the `teachers` delta reads moves only the encoded
    /// form of its changed fraction. The publish write is priced raw —
    /// the socket transport's `PUBLISH` stream is uncompressed, and
    /// while a `CKPT0004` spool publisher does write encoded windows,
    /// pricing the write at full cost keeps the model an upper bound on
    /// every backend instead of overstating socket savings. The
    /// transport's per-window never-larger rule bounds `ratio` at 1.0
    /// (clamped here), where this degenerates to
    /// [`ClusterModel::delta_exchange_time`]; a converged run's
    /// near-identical planes push the read term toward the RLE floor.
    pub fn compressed_exchange_time(
        &self,
        teachers: usize,
        changed_fraction: f64,
        ratio: f64,
    ) -> f64 {
        let r = ratio.clamp(0.0, 1.0);
        let f = changed_fraction.clamp(0.0, 1.0);
        self.sharded_exchange_time(teachers, (f * r * self.model_bytes as f64) as u64)
    }

    /// [`ClusterModel::compressed_exchange_time`] priced by codec instead
    /// of a hand-picked ratio (see [`codec_wire_ratio`]) — the shorthand
    /// the bench and CLI summaries use for lossy exchange projections.
    pub fn codec_exchange_time(
        &self,
        teachers: usize,
        changed_fraction: f64,
        codec: crate::codistill::transport::Codec,
    ) -> f64 {
        self.compressed_exchange_time(teachers, changed_fraction, codec_wire_ratio(codec))
    }

    /// Exchange wall time when `dead` of a reader's `teachers` peers are
    /// unreachable (§2.2: the coordinator's liveness table drops them):
    /// the write and the live reads move planes at full bandwidth, while
    /// each dead peer costs only a failed probe at latency scale — the
    /// run degrades smoothly instead of stalling like a synchronous
    /// barrier would.
    pub fn degraded_exchange_time(&self, teachers: usize, dead: usize) -> f64 {
        let dead = dead.min(teachers);
        self.full_exchange_time(teachers - dead) + dead as f64 * self.latency_s
    }

    /// Mean exchange bytes per step under publish-cadence skew: member
    /// `i` publishes (and is read) every `intervals[i]` steps instead of
    /// one shared reload interval. Equals
    /// [`ClusterModel::codistill_bytes_per_step`] when every interval is
    /// `reload_interval`.
    pub fn skewed_bytes_per_step(&self, intervals: &[u64]) -> f64 {
        if intervals.is_empty() {
            return 0.0;
        }
        let per_member: f64 = intervals
            .iter()
            .map(|&i| 2.0 * self.model_bytes as f64 / i.max(1) as f64)
            .sum();
        per_member / intervals.len() as f64
    }

    /// Per-step communication bytes for sync SGD vs codistillation —
    /// the §2.1 comparison, used by the ablation bench.
    pub fn sync_sgd_bytes_per_step(&self) -> u64 {
        // ring allreduce moves ~2×model per worker per step
        2 * self.model_bytes
    }

    pub fn codistill_bytes_per_step(&self) -> f64 {
        2.0 * self.model_bytes as f64 / self.reload_interval.max(1) as f64
    }

    // ---------------------------------------------------- serving tier

    /// Steady-state items/second of a micro-batching inference server at
    /// a given batch size: each batch pays a fixed `batch_overhead_s`
    /// (dispatch, plane snapshot, queue bookkeeping) plus
    /// `item_cost_s` per feature item, so throughput rises with the
    /// batch and saturates toward `1/item_cost_s` — the
    /// throughput-vs-batch-size curve `sections.serving` tracks.
    pub fn serving_throughput(
        &self,
        batch_items: usize,
        item_cost_s: f64,
        batch_overhead_s: f64,
    ) -> f64 {
        let b = batch_items.max(1) as f64;
        b / (batch_overhead_s.max(0.0) + b * item_cost_s.max(1e-12))
    }

    /// Background wall cost of installing one hot swap through the
    /// delta-aware subscription: fetch the `changed_fraction` of the
    /// plane whose digests moved, plus a probe latency. Runs off the
    /// request path (the subscription thread), so it prices subscriber
    /// bandwidth, not request latency; at fraction 1.0 it degenerates to
    /// one whole-plane read.
    pub fn hot_swap_install_time(&self, changed_fraction: f64) -> f64 {
        let f = changed_fraction.clamp(0.0, 1.0);
        f * self.model_bytes as f64 / self.bandwidth_bps + self.latency_s
    }

    /// Request-visible stall of the atomic plane swap itself: a pointer
    /// flip under a briefly-held lock — latency-scale, independent of
    /// plane size. The zero-downtime claim in one number: compare with
    /// [`ClusterModel::serving_restart_stall`], the naive alternative.
    pub fn swap_stall_time(&self) -> f64 {
        self.latency_s
    }

    /// Request-visible stall of the naive alternative to hot swap:
    /// drain, reload the whole plane, restart — a full-plane read on the
    /// serving path.
    pub fn serving_restart_stall(&self) -> f64 {
        self.model_bytes as f64 / self.bandwidth_bps + self.latency_s
    }

    /// Items/second retained when a hot swap lands every
    /// `swap_interval_s` *and* the install shares the serving core
    /// (worst case — a dedicated subscription thread loses nothing):
    /// steady-state throughput scaled by the fraction of the interval
    /// not spent installing.
    pub fn serving_capacity_under_swaps(
        &self,
        batch_items: usize,
        item_cost_s: f64,
        batch_overhead_s: f64,
        swap_interval_s: f64,
        changed_fraction: f64,
    ) -> f64 {
        let t = self.serving_throughput(batch_items, item_cost_s, batch_overhead_s);
        if swap_interval_s <= 0.0 {
            return 0.0;
        }
        let busy = (self.hot_swap_install_time(changed_fraction) / swap_interval_s).min(1.0);
        t * (1.0 - busy)
    }
}

/// Expected teacher staleness (in steps) when the teacher publishes every
/// `publish_interval` steps and the reader reloads every
/// `reload_interval`: on average half of each cadence elapses between a
/// publication and the reload that uses it, and another half-reload while
/// the installed copy ages — the analytic twin of the coordinator's
/// per-member cadence skew.
pub fn expected_staleness_steps(reload_interval: u64, publish_interval: u64) -> f64 {
    (reload_interval as f64 + publish_interval as f64) / 2.0
}

/// Levels in a relay tree serving `readers` leaves at `fanout` children
/// per node: the smallest `d` with `fanout^d >= readers` (ceil of
/// log_fanout), never below 1 — even a single reader crosses one
/// store-and-forward hop once a relay tier exists. `fanout <= 1`
/// degenerates to a chain of `readers` hops.
pub fn relay_tree_depth(readers: usize, fanout: usize) -> u32 {
    let readers = readers.max(1);
    if fanout <= 1 {
        return readers as u32;
    }
    let mut depth = 1u32;
    let mut reach = fanout;
    while reach < readers {
        reach = reach.saturating_mul(fanout);
        depth += 1;
    }
    depth
}

/// Steady-state wire bytes per raw payload byte for each window codec,
/// as priced by [`ClusterModel::codec_exchange_time`]:
///
/// * `Raw` — 1.0 by definition;
/// * `Shuffle` — ~0.55, the byte-shuffle + RLE ratio the hotpath bench
///   measures on converging-run planes (high-entropy mantissa bytes,
///   compressible sign/exponent bytes);
/// * `Fp16` — exactly 0.5: two wire bytes per 4-byte element;
/// * `Int8` — ~0.26: one code byte per element plus the 4-byte
///   per-window scale header, amortized over bench-sized windows.
///
/// These are modelling constants for capacity planning, not guarantees —
/// the transport's never-larger rule only bounds each window at 1.0.
pub fn codec_wire_ratio(codec: crate::codistill::transport::Codec) -> f64 {
    use crate::codistill::transport::Codec;
    match codec {
        Codec::Raw => 1.0,
        Codec::Shuffle => 0.55,
        Codec::Fp16 => 0.5,
        Codec::Int8 => 0.26,
    }
}

/// Analytic price of one coordinator member's run (see
/// [`ClusterModel::coordinator_run_time`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoordinatorRunCost {
    /// Wall-clock seconds for the member's `total_steps`.
    pub wall_s: f64,
    /// Mean expected teacher staleness (in steps) over the cohort's
    /// publish cadences, at this member's reload interval.
    pub expected_staleness_steps: f64,
}

impl ClusterModel {
    /// Wall-clock pricing of one coordinator member's run, composed from
    /// the existing analytic pieces:
    ///
    /// * per-step compute + group allreduce (`compute_mean_s`,
    ///   [`ClusterModel::allreduce_time`]);
    /// * one [`ClusterModel::degraded_exchange_time`] per reload interval
    ///   — `dead` of the member's `teachers` cost a probe, not a stall;
    /// * the cohort's publish-cadence skew priced by
    ///   [`ClusterModel::skewed_bytes_per_step`], beyond the member's own
    ///   reload-cadence write already inside the exchange term;
    /// * the matching [`expected_staleness_steps`], averaged over the
    ///   cohort's cadences, reported alongside (staleness costs no wall
    ///   time — that delay-tolerance is the paper's point — but every
    ///   consumer of this model wants both numbers together).
    pub fn coordinator_run_time(
        &self,
        total_steps: u64,
        publish_intervals: &[u64],
        teachers: usize,
        dead: usize,
    ) -> CoordinatorRunCost {
        let steps = total_steps as f64;
        let reload = self.reload_interval.max(1);
        let step_term = steps * (self.compute_mean_s + self.allreduce_time());
        let exchange_term =
            (steps / reload as f64) * self.degraded_exchange_time(teachers, dead);
        // Cohort publish traffic under cadence skew, minus the one
        // reload-cadence write degraded_exchange_time already prices.
        let own_write = 2.0 * self.model_bytes as f64 / reload as f64;
        let skew_term = steps
            * (self.skewed_bytes_per_step(publish_intervals) - own_write).max(0.0)
            / self.bandwidth_bps;
        let staleness = if publish_intervals.is_empty() {
            expected_staleness_steps(reload, reload)
        } else {
            publish_intervals
                .iter()
                .map(|&p| expected_staleness_steps(reload, p))
                .sum::<f64>()
                / publish_intervals.len() as f64
        };
        CoordinatorRunCost {
            wall_s: step_term + exchange_term + skew_term,
            expected_staleness_steps: staleness,
        }
    }

    // ------------------------------------------- churn-scenario pricing
    //
    // Analytic wall-clock price of the `codistill::scenario` patterns,
    // so a scenario file can be costed before it is run (the same role
    // `coordinator_run_time` plays for a healthy run). Each returns the
    // *extra* seconds the pattern adds on top of a fault-free run.

    // --------------------------------------------- fan-out tier pricing
    //
    // The relay tier (`codistill::transport::Relay`) turns one hub with
    // R reader sockets into a tree: the hub feeds `fanout` relays, each
    // relay feeds `fanout` children, and readers hang off the leaves.
    // These methods price both shapes so `tree_depth`/`tree_fanout`
    // choices can be costed before a fleet is launched, mirroring what
    // `sections.fanout` measures on the real sockets.

    /// Wall time for one publication to reach every one of `readers`
    /// direct readers of a flat hub: the publish write plus `readers`
    /// delta reads serialized over the hub's single link, plus a probe
    /// latency per reader. The `changed_fraction` is the delta-exchange
    /// knob ([`ClusterModel::delta_exchange_time`]); at fraction 1.0
    /// every reader pulls the whole plane.
    pub fn hub_fanout_time(&self, readers: usize, changed_fraction: f64) -> f64 {
        self.delta_exchange_time(readers, changed_fraction) + readers as f64 * self.latency_s
    }

    /// Wall time for one publication to reach every leaf of a relay tree
    /// with `fanout` children per node: the publish write, then one
    /// level at a time — each node re-serves the changed fraction to its
    /// `fanout` children over its *own* link (levels fan out in
    /// parallel, so the critical path is one node's outbound traffic per
    /// level) plus a hop latency. Readers count as the final level's
    /// children, so the critical path has
    /// [`relay_tree_depth`]`(readers, fanout)` store-and-forward hops.
    pub fn relay_tree_fanout_time(
        &self,
        readers: usize,
        fanout: usize,
        changed_fraction: f64,
    ) -> f64 {
        let f = changed_fraction.clamp(0.0, 1.0);
        let depth = relay_tree_depth(readers, fanout) as f64;
        let write = self.model_bytes as f64 / self.bandwidth_bps;
        let per_level =
            fanout as f64 * f * self.model_bytes as f64 / self.bandwidth_bps + self.latency_s;
        write + depth * per_level
    }

    /// Extra staleness a relay tree adds over the flat hub: each
    /// store-and-forward hop waits at most one relay refresh interval
    /// before a fresh plane moves down a level — the price paid for the
    /// fan-out, bounded and linear in depth (the paper's premise is that
    /// this bounded staleness is tolerable).
    pub fn relay_tree_staleness_s(
        &self,
        readers: usize,
        fanout: usize,
        poll_interval_s: f64,
    ) -> f64 {
        relay_tree_depth(readers, fanout) as f64 * poll_interval_s.max(0.0)
    }

    /// A spot-preemption wave: `victims` members each lose
    /// `mean_down_steps` steps of compute, then pay a bootstrap read plus
    /// a rejoin publish when they come back.
    pub fn preemption_wave_cost(&self, victims: usize, mean_down_steps: f64) -> f64 {
        let rejoin = 2.0 * self.model_bytes as f64 / self.bandwidth_bps + self.latency_s;
        victims as f64 * (mean_down_steps.max(0.0) * self.compute_mean_s + rejoin)
    }

    /// A zone blackout: `zone_members` keep training but every
    /// publication over the `window_steps` window is dropped — the writes
    /// are wasted, and each member pays one full catch-up read when the
    /// zone comes back.
    pub fn zone_outage_cost(&self, zone_members: usize, window_steps: u64) -> f64 {
        let cadence = self.reload_interval.max(1) as f64;
        let wasted_writes = (window_steps as f64 / cadence).max(1.0);
        let per_member =
            (wasted_writes + 1.0) * self.model_bytes as f64 / self.bandwidth_bps;
        zone_members as f64 * per_member
    }

    /// A flash crowd: `joiners` members bootstrap at once, each pulling a
    /// full plane and publishing its own — a serialized burst on the
    /// shared exchange link.
    pub fn flash_crowd_cost(&self, joiners: usize) -> f64 {
        joiners as f64 * (2.0 * self.model_bytes as f64 / self.bandwidth_bps + self.latency_s)
    }

    /// A flaky network under a retrying client: `reads` exchange reads
    /// each fail independently with probability `fail_p` per attempt, and
    /// the retry layer re-issues up to `max_attempts` total. The price is
    /// the expected *extra* attempts (`E[attempts] − 1`, geometric
    /// truncated at the budget), each costing a plane read plus a probe.
    pub fn flaky_net_cost(&self, reads: u64, fail_p: f64, max_attempts: u32) -> f64 {
        let p = fail_p.clamp(0.0, 0.999);
        let k = max_attempts.max(1) as i32;
        // E[attempts] for a truncated geometric: (1 - p^k) / (1 - p).
        let expected_attempts = (1.0 - p.powi(k)) / (1.0 - p);
        let extra = (expected_attempts - 1.0).max(0.0);
        reads as f64 * extra * (self.model_bytes as f64 / self.bandwidth_bps + self.latency_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_grows_with_latency_term() {
        let mut m = ClusterModel::gpu_cluster(2, 40_000_000);
        let t2 = m.allreduce_time();
        m.workers = 256;
        let t256 = m.allreduce_time();
        assert!(t256 > t2, "{t256} !> {t2}");
        m.workers = 1;
        assert_eq!(m.allreduce_time(), 0.0);
    }

    #[test]
    fn straggler_tail_grows_with_workers() {
        let m8 = ClusterModel::gpu_cluster(8, 1);
        let m256 = ClusterModel::gpu_cluster(256, 1);
        let t8 = m8.mean_step_time(400, 1);
        let t256 = m256.mean_step_time(400, 1);
        assert!(
            t256 > t8 * 1.1,
            "max-of-256 ({t256}) should exceed max-of-8 ({t8}) by >10%"
        );
    }

    #[test]
    fn step_time_positive_and_reproducible() {
        let m = ClusterModel::gpu_cluster(16, 40_000_000);
        let mut r1 = Pcg64::new(5);
        let mut r2 = Pcg64::new(5);
        let a = m.step_time(&mut r1);
        let b = m.step_time(&mut r2);
        assert!(a > 0.0);
        assert_eq!(a, b);
    }

    #[test]
    fn codistill_communication_is_cheaper() {
        // The §2.1 claim: per-step bytes for codistillation (amortized
        // checkpoint reads) are far below sync SGD's allreduce traffic.
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        assert!(m.codistill_bytes_per_step() * 10.0 < m.sync_sgd_bytes_per_step() as f64);
    }

    #[test]
    fn exchange_time_amortizes() {
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        let per_step = m.checkpoint_exchange_time() / m.reload_interval as f64;
        assert!(per_step < m.allreduce_time());
    }

    #[test]
    fn dead_members_cheapen_the_exchange_instead_of_stalling_it() {
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        // all-live degenerates to the full exchange
        assert_eq!(m.degraded_exchange_time(3, 0), m.full_exchange_time(3));
        // each death removes a plane read and adds only a probe latency
        let t_all = m.degraded_exchange_time(3, 0);
        let t_one_dead = m.degraded_exchange_time(3, 1);
        let t_all_dead = m.degraded_exchange_time(3, 3);
        assert!(t_one_dead < t_all, "{t_one_dead} !< {t_all}");
        assert!(t_all_dead < t_one_dead);
        // even with every teacher dead the member still pays its write
        assert!(t_all_dead >= m.full_exchange_time(0));
        // dead counts past the teacher set saturate
        assert_eq!(m.degraded_exchange_time(3, 9), m.degraded_exchange_time(3, 3));
    }

    #[test]
    fn skewed_cadences_price_between_their_extremes() {
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        // uniform skew equals the shared-interval price
        assert_eq!(
            m.skewed_bytes_per_step(&[50, 50, 50]),
            m.codistill_bytes_per_step()
        );
        let mixed = m.skewed_bytes_per_step(&[25, 50, 100]);
        let fast = m.skewed_bytes_per_step(&[25, 25, 25]);
        let slow = m.skewed_bytes_per_step(&[100, 100, 100]);
        assert!(mixed < fast && mixed > slow, "{slow} < {mixed} < {fast}");
        assert_eq!(m.skewed_bytes_per_step(&[]), 0.0);
        // staleness grows with either cadence
        assert!(expected_staleness_steps(50, 100) > expected_staleness_steps(50, 50));
        assert!(expected_staleness_steps(100, 50) > expected_staleness_steps(50, 50));
    }

    #[test]
    fn delta_exchange_prices_between_empty_and_full() {
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        for teachers in [1usize, 3, 8] {
            let full = m.full_exchange_time(teachers);
            // unchanged plane: only the member's own write remains
            assert_eq!(m.delta_exchange_time(teachers, 0.0), m.full_exchange_time(0));
            // the whole plane changed: delta degenerates to full
            assert_eq!(m.delta_exchange_time(teachers, 1.0), full);
            // steady state: strictly cheaper, monotone in the fraction
            let d05 = m.delta_exchange_time(teachers, 0.05);
            let d25 = m.delta_exchange_time(teachers, 0.25);
            assert!(d05 < d25 && d25 < full, "{d05} < {d25} < {full}");
        }
        // out-of-range fractions clamp instead of extrapolating
        assert_eq!(m.delta_exchange_time(3, 2.0), m.delta_exchange_time(3, 1.0));
        assert_eq!(m.delta_exchange_time(3, -1.0), m.delta_exchange_time(3, 0.0));
    }

    #[test]
    fn compressed_exchange_prices_under_delta() {
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        for teachers in [1usize, 3, 8] {
            for frac in [1.0f64, 0.25, 0.05] {
                let delta = m.delta_exchange_time(teachers, frac);
                // ratio 1.0: the codec never engaged — equals the delta
                // exchange exactly
                assert_eq!(m.compressed_exchange_time(teachers, frac, 1.0), delta);
                // real ratios are strictly cheaper and monotone
                let c50 = m.compressed_exchange_time(teachers, frac, 0.5);
                let c10 = m.compressed_exchange_time(teachers, frac, 0.1);
                assert!(c10 < c50 && c50 < delta, "{c10} < {c50} < {delta}");
            }
        }
        // the full stack of levers composes: full > delta > delta+codec
        let full = m.full_exchange_time(3);
        let delta = m.delta_exchange_time(3, 0.25);
        let codec = m.compressed_exchange_time(3, 0.25, 0.3);
        assert!(codec < delta && delta < full, "{codec} < {delta} < {full}");
        // out-of-range ratios clamp instead of extrapolating
        assert_eq!(
            m.compressed_exchange_time(3, 0.25, 2.0),
            m.compressed_exchange_time(3, 0.25, 1.0)
        );
        assert_eq!(
            m.compressed_exchange_time(3, 0.25, -1.0),
            m.compressed_exchange_time(3, 0.25, 0.0)
        );
    }

    #[test]
    fn codec_pricing_orders_the_codecs() {
        use crate::codistill::transport::Codec;
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        // raw pricing degenerates to the plain delta exchange
        assert_eq!(
            m.codec_exchange_time(3, 0.25, Codec::Raw),
            m.delta_exchange_time(3, 0.25)
        );
        // heavier quantization is strictly cheaper on the wire
        let raw = m.codec_exchange_time(3, 0.25, Codec::Raw);
        let shuf = m.codec_exchange_time(3, 0.25, Codec::Shuffle);
        let fp16 = m.codec_exchange_time(3, 0.25, Codec::Fp16);
        let int8 = m.codec_exchange_time(3, 0.25, Codec::Int8);
        assert!(
            int8 < fp16 && fp16 < shuf && shuf < raw,
            "{int8} < {fp16} < {shuf} < {raw}"
        );
        // and the int8 ratio prices ≥2× fewer read bytes than shuffle —
        // the same margin the hotpath bench pins on real payloads
        assert!(codec_wire_ratio(Codec::Int8) * 2.0 <= codec_wire_ratio(Codec::Shuffle));
        for c in [Codec::Raw, Codec::Shuffle, Codec::Fp16, Codec::Int8] {
            let r = codec_wire_ratio(c);
            assert!(r > 0.0 && r <= 1.0, "{c:?} ratio {r} out of range");
        }
    }

    #[test]
    fn serving_throughput_rises_with_batch_and_saturates() {
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        let (item, overhead) = (50e-6, 200e-6);
        // bigger batches amortize the per-batch overhead
        let t1 = m.serving_throughput(1, item, overhead);
        let t16 = m.serving_throughput(16, item, overhead);
        let t256 = m.serving_throughput(256, item, overhead);
        assert!(t1 < t16 && t16 < t256, "{t1} < {t16} < {t256}");
        // ... but never past the per-item compute ceiling
        let ceiling = 1.0 / item;
        assert!(t256 < ceiling);
        // with no overhead the ceiling is reached at any batch size
        assert_eq!(m.serving_throughput(1, item, 0.0), ceiling);
        // batch 0 clamps to 1 instead of dividing by zero
        assert_eq!(
            m.serving_throughput(0, item, overhead),
            m.serving_throughput(1, item, overhead)
        );
    }

    #[test]
    fn hot_swap_stalls_price_under_a_restart() {
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        // the swap itself is a pointer flip: latency-scale, plane-size-free
        assert_eq!(m.swap_stall_time(), m.latency_s);
        assert!(m.swap_stall_time() < m.serving_restart_stall());
        // background install cost is monotone in the changed fraction and
        // degenerates to one whole-plane read at fraction 1.0
        let i05 = m.hot_swap_install_time(0.05);
        let i25 = m.hot_swap_install_time(0.25);
        let full = m.hot_swap_install_time(1.0);
        assert!(i05 < i25 && i25 < full, "{i05} < {i25} < {full}");
        assert_eq!(full, m.serving_restart_stall());
        // out-of-range fractions clamp instead of extrapolating
        assert_eq!(m.hot_swap_install_time(2.0), m.hot_swap_install_time(1.0));
        assert_eq!(m.hot_swap_install_time(-1.0), m.hot_swap_install_time(0.0));
        // capacity under swaps: delta installs retain more throughput than
        // full-plane installs, and neither exceeds the swap-free rate
        let (item, overhead) = (50e-6, 200e-6);
        let free = m.serving_throughput(64, item, overhead);
        let delta = m.serving_capacity_under_swaps(64, item, overhead, 1.0, 0.05);
        let heavy = m.serving_capacity_under_swaps(64, item, overhead, 1.0, 1.0);
        assert!(heavy < delta && delta < free, "{heavy} < {delta} < {free}");
    }

    #[test]
    fn coordinator_run_time_pins_between_healthy_and_degraded_bounds() {
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        let intervals = [50u64, 50, 50];
        let healthy = m.coordinator_run_time(1000, &intervals, 3, 0);
        let one_dead = m.coordinator_run_time(1000, &intervals, 3, 1);
        let all_dead = m.coordinator_run_time(1000, &intervals, 3, 3);
        // dead peers remove plane reads (probe-priced), so the healthy run
        // is the upper bound and the fully-degraded run the lower
        assert!(
            all_dead.wall_s < one_dead.wall_s && one_dead.wall_s < healthy.wall_s,
            "{} < {} < {}",
            all_dead.wall_s,
            one_dead.wall_s,
            healthy.wall_s
        );
        // even fully degraded, compute + own writes remain
        let floor = 1000.0 * (m.compute_mean_s + m.allreduce_time());
        assert!(all_dead.wall_s > floor);
        // cadence skew beyond the member's own reload write adds wall time
        let skewed = m.coordinator_run_time(1000, &[10, 10, 10], 3, 0);
        assert!(skewed.wall_s > healthy.wall_s);
        // staleness reports the cohort mean of expected_staleness_steps
        assert_eq!(
            healthy.expected_staleness_steps,
            expected_staleness_steps(50, 50)
        );
        let mixed = m.coordinator_run_time(1000, &[25, 100], 3, 0);
        assert_eq!(
            mixed.expected_staleness_steps,
            (expected_staleness_steps(50, 25) + expected_staleness_steps(50, 100)) / 2.0
        );
        // no cohort given: the member's own cadence stands in
        assert_eq!(
            m.coordinator_run_time(1000, &[], 3, 0).expected_staleness_steps,
            expected_staleness_steps(50, 50)
        );
    }

    #[test]
    fn scenario_prices_scale_with_their_knobs() {
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        // preemption: more victims or longer downtime costs more
        let wave = m.preemption_wave_cost(25, 25.0);
        assert!(wave > 0.0);
        assert!(m.preemption_wave_cost(50, 25.0) > wave);
        assert!(m.preemption_wave_cost(25, 50.0) > wave);
        // a zero-length preemption still prices the rejoin traffic
        assert!(m.preemption_wave_cost(25, 0.0) > 0.0);
        // zone outage: wider zones and longer windows cost more
        let outage = m.zone_outage_cost(20, 40);
        assert!(m.zone_outage_cost(40, 40) > outage);
        assert!(m.zone_outage_cost(20, 400) > outage);
        // flash crowd: linear in joiners
        assert_eq!(m.flash_crowd_cost(20), 10.0 * m.flash_crowd_cost(2));
        // flaky net: a perfect network retries nothing, and more failure
        // costs more up to the attempt budget
        assert_eq!(m.flaky_net_cost(100, 0.0, 5), 0.0);
        let flaky = m.flaky_net_cost(100, 0.3, 5);
        assert!(flaky > 0.0);
        assert!(m.flaky_net_cost(100, 0.6, 5) > flaky);
        assert!(m.flaky_net_cost(200, 0.3, 5) > flaky);
        // a single-attempt budget never pays extra attempts
        assert_eq!(m.flaky_net_cost(100, 0.3, 1), 0.0);
    }

    #[test]
    fn relay_tree_depth_is_ceil_log_fanout() {
        assert_eq!(relay_tree_depth(8, 8), 1);
        assert_eq!(relay_tree_depth(9, 8), 2);
        assert_eq!(relay_tree_depth(64, 8), 2);
        assert_eq!(relay_tree_depth(512, 8), 3);
        assert_eq!(relay_tree_depth(1000, 8), 4);
        // even one reader crosses one hop; fanout 1 is a chain
        assert_eq!(relay_tree_depth(1, 8), 1);
        assert_eq!(relay_tree_depth(0, 8), 1);
        assert_eq!(relay_tree_depth(5, 1), 5);
    }

    #[test]
    fn relay_tree_beats_the_flat_hub_at_scale() {
        let m = ClusterModel::gpu_cluster(8, 40_000_000);
        for frac in [1.0f64, 0.25, 0.05] {
            // O(512) readers: 3 levels of 8-way fan-out move ~24 plane
            // fractions on the critical path vs the hub's 512 serialized
            // reads — an order of magnitude, growing with reader count
            let hub = m.hub_fanout_time(512, frac);
            let tree = m.relay_tree_fanout_time(512, 8, frac);
            assert!(tree < hub / 4.0, "frac {frac}: tree {tree} !<< hub {hub}");
            // ... and the gap widens as the fleet grows
            let hub1k = m.hub_fanout_time(1000, frac);
            let tree1k = m.relay_tree_fanout_time(1000, 8, frac);
            assert!(hub1k - tree1k > hub - tree);
        }
        // tiny fleets: the tree's store-and-forward hop buys nothing —
        // a hub serving fewer readers than one node's fanout is cheaper
        let hub = m.hub_fanout_time(4, 0.25);
        let tree = m.relay_tree_fanout_time(4, 8, 0.25);
        assert!(hub <= tree, "hub {hub} !<= tree {tree} at 4 readers");
        // staleness is the price: linear in depth, zero for the flat hub
        let s512 = m.relay_tree_staleness_s(512, 8, 0.005);
        assert_eq!(s512, 3.0 * 0.005);
        assert!(m.relay_tree_staleness_s(1000, 8, 0.005) > s512);
        assert_eq!(m.relay_tree_staleness_s(512, 8, -1.0), 0.0);
    }

    #[test]
    fn sharded_exchange_beats_full_plane_with_multiple_teachers() {
        let m = ClusterModel::gpu_cluster(128, 40_000_000);
        // each reader fetches a quarter of the plane's windows
        let fetched = m.model_bytes / 4;
        for teachers in [2usize, 3, 7] {
            let full = m.full_exchange_time(teachers);
            let sharded = m.sharded_exchange_time(teachers, fetched);
            assert!(
                sharded < full,
                "W={teachers}: sharded {sharded} !< full {full}"
            );
        }
        // savings grow with teacher count: the write amortizes, the reads shrink
        let gain2 = m.full_exchange_time(2) - m.sharded_exchange_time(2, fetched);
        let gain8 = m.full_exchange_time(8) - m.sharded_exchange_time(8, fetched);
        assert!(gain8 > gain2);
        // degenerate cases: fetching the whole plane equals full-plane cost,
        // and the single-teacher wrapper keeps its historical value
        assert_eq!(
            m.sharded_exchange_time(3, m.model_bytes),
            m.full_exchange_time(3)
        );
        assert_eq!(m.checkpoint_exchange_time(), m.full_exchange_time(1));
    }
}

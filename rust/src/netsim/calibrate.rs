//! Fit a [`ClusterModel`] from a recorded `codistill::obs` trace.
//!
//! The analytic model in [`crate::netsim`] prices exchanges from
//! hand-picked constants (bandwidth, latency, plane size). A real run
//! recorded with `--trace` carries the measured side of the same story:
//! every `publish` and `fetch` event holds the bytes it moved and the
//! wall microseconds it took, and every `delta_install` holds the
//! changed-window accounting. [`calibrate`] closes the loop — it fits
//! the per-byte and per-exchange constants from the trace by least
//! squares, rebuilds a [`ClusterModel`] from them, and reports how far
//! the model's [`ClusterModel::compressed_exchange_time`] lands from
//! the wall time the trace actually measured (the ROADMAP's
//! "trace-validated netsim").
//!
//! The fit is the obvious linear one: each timed sample (a publish or a
//! fetch) is a point `(bytes, seconds)`, and
//!
//! ```text
//!   seconds ≈ latency_s + bytes / bandwidth_bps
//! ```
//!
//! so slope and intercept of the least-squares line give the two
//! transport constants. The exchange *shape* constants come from
//! counting: plane size is the largest published plane, teachers per
//! publish is the fetch/publish ratio, the changed fraction and wire
//! ratio come from the steady-state (non-full) delta installs, and the
//! reload interval is the median publish step gap.

use super::ClusterModel;
use crate::codistill::obs::{Event, EventJournal};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One timed byte-move sample lifted from the trace.
#[derive(Debug, Clone, Copy)]
struct Sample {
    bytes: u64,
    dur_s: f64,
}

/// A fitted model plus the evidence behind it (see [`calibrate`]).
#[derive(Debug, Clone)]
pub struct Calibration {
    /// The model rebuilt from the trace: fitted `bandwidth_bps` /
    /// `latency_s` / `model_bytes` / `workers` / `reload_interval`;
    /// compute and straggler knobs keep [`ClusterModel::gpu_cluster`]
    /// defaults (a trace of exchange events cannot see compute).
    pub model: ClusterModel,
    /// Timed publish/fetch samples the line was fitted over.
    pub samples: usize,
    /// Teacher reads per publish observed in the trace.
    pub teachers: usize,
    /// Mean changed-window fraction over steady-state delta installs
    /// (1.0 when the trace has none).
    pub changed_fraction: f64,
    /// Mean wire bytes / raw changed bytes over steady-state delta
    /// installs (1.0 when the trace has none).
    pub wire_ratio: f64,
    /// Measured mean wall seconds per exchange round: one publish plus
    /// `teachers` steady-state fetches (cold full fetches excluded).
    pub measured_exchange_s: f64,
    /// The fitted model's [`ClusterModel::compressed_exchange_time`]
    /// at the observed teachers / changed fraction / wire ratio.
    pub modeled_exchange_s: f64,
}

impl Calibration {
    /// |modeled − measured| / measured.
    pub fn rel_error(&self) -> f64 {
        if self.measured_exchange_s > 0.0 {
            (self.modeled_exchange_s - self.measured_exchange_s).abs() / self.measured_exchange_s
        } else {
            f64::INFINITY
        }
    }

    /// Human-readable modeled-vs-measured summary (the CLI report).
    pub fn report(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "[calibrate] fitted over {} samples: bandwidth={:.3e} B/s latency={:.1}us",
            self.samples,
            self.model.bandwidth_bps,
            self.model.latency_s * 1e6,
        );
        let _ = writeln!(
            out,
            "[calibrate] exchange shape: workers={} model_bytes={} reload_interval={} \
             teachers={} changed_fraction={:.3} wire_ratio={:.3}",
            self.model.workers,
            self.model.model_bytes,
            self.model.reload_interval,
            self.teachers,
            self.changed_fraction,
            self.wire_ratio,
        );
        let _ = writeln!(
            out,
            "[calibrate] exchange wall: measured={:.3e}s modeled={:.3e}s rel_error={:.1}%",
            self.measured_exchange_s,
            self.modeled_exchange_s,
            self.rel_error() * 100.0,
        );
        out
    }
}

/// Fit a [`ClusterModel`] from a `--trace` JSONL dump (see module docs).
///
/// Errors when the trace parses but holds no publish events, or no
/// timed samples to fit from — a trace recorded under a simulated clock
/// still works (the durations are synthetic but self-consistent), it
/// just calibrates the simulated medium instead of a real one.
pub fn calibrate(trace: &str) -> Result<Calibration> {
    let journal = EventJournal::from_jsonl(trace)?;

    // (member, step, bytes, dur_us) for publishes; fetches paired with
    // the delta install recorded by the same cache call (pair by order:
    // the cache records Fetch then DeltaInstall back to back).
    let mut publishes: Vec<(usize, u64, u64, u64)> = Vec::new();
    let mut fetches: Vec<(u64, u64, Option<bool>)> = Vec::new(); // (bytes, dur_us, full)
    let mut pending_fetch: Vec<usize> = Vec::new(); // indices awaiting their install
    let mut installs: Vec<(bool, u64, u64, u64)> = Vec::new(); // (full, moved, unchanged, bytes)

    for te in &journal.events {
        match &te.event {
            Event::Publish { member, step, bytes, dur_us } => {
                publishes.push((*member, *step, *bytes, *dur_us));
            }
            Event::Fetch { bytes, dur_us, .. } => {
                pending_fetch.push(fetches.len());
                fetches.push((*bytes, *dur_us, None));
            }
            Event::DeltaInstall { full, moved, unchanged, bytes, .. } => {
                installs.push((*full, *moved, *unchanged, *bytes));
                if let Some(i) = pending_fetch.pop() {
                    fetches[i].2 = Some(*full);
                }
            }
            _ => {}
        }
    }

    if publishes.is_empty() {
        bail!("trace has no publish events to calibrate from");
    }

    // --- transport constants: least-squares dur_s = a + bytes/bw ------
    let mut pts: Vec<Sample> = Vec::new();
    for &(_, _, bytes, dur_us) in &publishes {
        if dur_us > 0 {
            pts.push(Sample { bytes, dur_s: dur_us as f64 * 1e-6 });
        }
    }
    for &(bytes, dur_us, _) in &fetches {
        if dur_us > 0 {
            pts.push(Sample { bytes, dur_s: dur_us as f64 * 1e-6 });
        }
    }
    if pts.is_empty() {
        bail!("trace has no timed publish/fetch samples (all dur_us = 0)");
    }
    let (bandwidth_bps, latency_s) = fit_line(&pts);

    // --- exchange shape ----------------------------------------------
    let model_bytes = publishes.iter().map(|&(_, _, b, _)| b).max().unwrap_or(0);
    let workers = {
        let mut m: Vec<usize> = publishes.iter().map(|&(w, ..)| w).collect();
        m.sort_unstable();
        m.dedup();
        m.len()
    };
    let reload_interval = median_publish_gap(&publishes).unwrap_or(50);
    let teachers = if publishes.is_empty() {
        0
    } else {
        ((fetches.len() as f64 / publishes.len() as f64).round() as usize).max(1)
    };

    // Steady-state delta shape: full installs are the cold start, not
    // the steady state the model prices.
    let steady: Vec<&(bool, u64, u64, u64)> = installs
        .iter()
        .filter(|&&(full, moved, unchanged, _)| !full && moved + unchanged > 0)
        .collect();
    let changed_fraction = if steady.is_empty() {
        1.0
    } else {
        steady
            .iter()
            .map(|&&(_, moved, unchanged, _)| moved as f64 / (moved + unchanged) as f64)
            .sum::<f64>()
            / steady.len() as f64
    };
    let wire_ratio = if steady.is_empty() || model_bytes == 0 || changed_fraction <= 0.0 {
        1.0
    } else {
        let r = steady
            .iter()
            .map(|&&(_, moved, unchanged, bytes)| {
                let f = moved as f64 / (moved + unchanged) as f64;
                if f > 0.0 {
                    bytes as f64 / (f * model_bytes as f64)
                } else {
                    1.0
                }
            })
            .sum::<f64>()
            / steady.len() as f64;
        r.clamp(0.0, 1.0)
    };

    // --- measured vs modeled wall per exchange round ------------------
    let timed_pub: Vec<f64> = publishes
        .iter()
        .filter(|&&(_, _, _, d)| d > 0)
        .map(|&(_, _, _, d)| d as f64 * 1e-6)
        .collect();
    // Steady fetches: the pairing above marks each fetch with its
    // install's `full` flag; unpaired fetches (no delta cache in the
    // stack) count as steady.
    let timed_fetch: Vec<f64> = fetches
        .iter()
        .filter(|&&(_, d, full)| d > 0 && full != Some(true))
        .map(|&(_, d, _)| d as f64 * 1e-6)
        .collect();
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let measured_exchange_s = mean(&timed_pub) + teachers as f64 * mean(&timed_fetch);

    let mut model = ClusterModel::gpu_cluster(workers.max(1), model_bytes);
    model.bandwidth_bps = bandwidth_bps;
    model.latency_s = latency_s;
    model.reload_interval = reload_interval;
    let modeled_exchange_s = model.compressed_exchange_time(teachers, changed_fraction, wire_ratio);

    Ok(Calibration {
        model,
        samples: pts.len(),
        teachers,
        changed_fraction,
        wire_ratio,
        measured_exchange_s,
        modeled_exchange_s,
    })
}

/// Least-squares `dur_s = latency + bytes/bandwidth` over the samples.
/// Degenerate inputs (one distinct size, or a non-positive slope) fall
/// back to the aggregate rate with zero base latency.
fn fit_line(pts: &[Sample]) -> (f64, f64) {
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.bytes as f64).sum();
    let sy: f64 = pts.iter().map(|p| p.dur_s).sum();
    let sxx: f64 = pts.iter().map(|p| (p.bytes as f64) * (p.bytes as f64)).sum();
    let sxy: f64 = pts.iter().map(|p| (p.bytes as f64) * p.dur_s).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() > f64::EPSILON {
        let slope = (n * sxy - sx * sy) / denom;
        if slope > 0.0 {
            let intercept = (sy - slope * sx) / n;
            return (1.0 / slope, intercept.max(0.0));
        }
    }
    // Fallback: aggregate bytes-per-second, all time on the wire.
    if sy > 0.0 {
        (sx / sy, 0.0)
    } else {
        (1.0, 0.0)
    }
}

/// Median gap between consecutive published steps, per member, pooled.
fn median_publish_gap(publishes: &[(usize, u64, u64, u64)]) -> Option<u64> {
    let mut per_member: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
    for &(member, step, _, _) in publishes {
        per_member.entry(member).or_default().push(step);
    }
    let mut gaps: Vec<u64> = Vec::new();
    for steps in per_member.values_mut() {
        steps.sort_unstable();
        gaps.extend(steps.windows(2).map(|w| w[1] - w[0]).filter(|&g| g > 0));
    }
    if gaps.is_empty() {
        return None;
    }
    gaps.sort_unstable();
    Some(gaps[gaps.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a synthetic trace: `rounds` exchange rounds of 2 members,
    /// plane of `model_bytes`, durations `base_us + bytes/1000` (i.e. a
    /// 1 GB/s medium with `base_us` latency), steady delta installs
    /// moving 2 of 8 windows at an int8-ish wire ratio.
    fn synthetic_trace(rounds: u64, model_bytes: u64) -> String {
        let mut out = String::new();
        let mut t = 0u64;
        let dur = |bytes: u64| 200 + bytes / 1000;
        let delta_bytes = 260 * model_bytes / 4000; // 0.25 changed × 0.26 wire
        for round in 1..=rounds {
            let step = round * 50;
            for member in 0..2usize {
                t += 7;
                out.push_str(&format!(
                    "{{\"t_us\":{t},\"ev\":\"publish\",\"member\":{member},\"step\":{step},\"bytes\":{model_bytes},\"dur_us\":{}}}\n",
                    dur(model_bytes)
                ));
            }
            for member in 0..2usize {
                let teacher = 1 - member;
                let (bytes, full) = if round == 1 {
                    (model_bytes, true)
                } else {
                    (delta_bytes, false)
                };
                t += 5;
                out.push_str(&format!(
                    "{{\"t_us\":{t},\"ev\":\"fetch\",\"member\":{teacher},\"step\":{step},\"bytes\":{bytes},\"dur_us\":{}}}\n",
                    dur(bytes)
                ));
                t += 3;
                let (moved, unchanged) = if full { (8, 0) } else { (2, 6) };
                out.push_str(&format!(
                    "{{\"t_us\":{t},\"ev\":\"delta_install\",\"member\":{teacher},\"step\":{step},\"full\":{full},\"moved\":{moved},\"unchanged\":{unchanged},\"encoded\":{moved},\"bytes\":{bytes}}}\n"
                ));
            }
        }
        out
    }

    #[test]
    fn fits_the_synthetic_medium_within_tolerance() {
        let trace = synthetic_trace(10, 4_000_000);
        let cal = calibrate(&trace).unwrap();
        // 1 GB/s, 200us latency, 4 MB plane, 2 workers, interval 50.
        assert!(
            (cal.model.bandwidth_bps - 1e9).abs() / 1e9 < 0.05,
            "bandwidth {:.3e}",
            cal.model.bandwidth_bps
        );
        assert!(
            (cal.model.latency_s - 200e-6).abs() < 50e-6,
            "latency {:.1}us",
            cal.model.latency_s * 1e6
        );
        assert_eq!(cal.model.model_bytes, 4_000_000);
        assert_eq!(cal.model.workers, 2);
        assert_eq!(cal.model.reload_interval, 50);
        assert_eq!(cal.teachers, 1);
        assert!((cal.changed_fraction - 0.25).abs() < 1e-9);
        assert!((cal.wire_ratio - 0.26).abs() < 1e-3, "ratio {}", cal.wire_ratio);
        // The headline acceptance bound: modeled within 25% of measured.
        assert!(cal.rel_error() < 0.25, "rel_error {:.3}", cal.rel_error());
        let report = cal.report();
        assert!(report.contains("rel_error"), "{report}");
    }

    #[test]
    fn cold_full_fetches_are_excluded_from_the_steady_state() {
        // One round only: every fetch is the cold full fetch, so the
        // steady-state delta shape falls back to full-plane constants.
        let trace = synthetic_trace(1, 4_000_000);
        let cal = calibrate(&trace).unwrap();
        assert_eq!(cal.changed_fraction, 1.0);
        assert_eq!(cal.wire_ratio, 1.0);
    }

    #[test]
    fn empty_and_eventless_traces_error() {
        assert!(calibrate("").is_err());
        // parseable but publish-free
        let only_fault = "{\"t_us\":1,\"ev\":\"fault\",\"kind\":\"dropped-fetch\",\"member\":0,\"salt\":9}\n";
        assert!(calibrate(only_fault).is_err());
    }

    #[test]
    fn fallback_rate_fit_on_a_single_sample_size() {
        // Every sample the same size: the line is degenerate, the
        // aggregate-rate fallback still produces a usable bandwidth.
        let mut trace = String::new();
        for i in 0..4 {
            trace.push_str(&format!(
                "{{\"t_us\":{},\"ev\":\"publish\",\"member\":0,\"step\":{},\"bytes\":1000000,\"dur_us\":1000}}\n",
                i + 1,
                (i + 1) * 50
            ));
        }
        let cal = calibrate(&trace).unwrap();
        assert!((cal.model.bandwidth_bps - 1e9).abs() / 1e9 < 1e-6);
        assert_eq!(cal.model.latency_s, 0.0);
    }
}

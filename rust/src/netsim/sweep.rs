//! Closed-form worker sweeps over the cluster model (Fig 1b's x-axis).

use crate::netsim::ClusterModel;

/// Step-time table across worker counts for a fixed model size.
pub fn step_time_sweep(
    workers: &[usize],
    model_bytes: u64,
    samples: usize,
    seed: u64,
) -> Vec<(usize, f64)> {
    workers
        .iter()
        .map(|&w| {
            let m = ClusterModel::gpu_cluster(w, model_bytes);
            (w, m.mean_step_time(samples, seed ^ w as u64))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_monotone_in_workers() {
        let s = step_time_sweep(&[8, 32, 128, 256], 40_000_000, 300, 7);
        assert_eq!(s.len(), 4);
        for pair in s.windows(2) {
            assert!(
                pair[1].1 >= pair[0].1 * 0.98,
                "step time should not improve with more sync workers: {s:?}"
            );
        }
        // 256 workers must be visibly worse than 8 (the Fig 1b cliff).
        assert!(s[3].1 > s[0].1 * 1.05);
    }
}

//! Mini property-testing harness (no proptest offline).
//!
//! [`forall`] runs a property over `n` seeded random cases; on failure it
//! reports the failing case seed so the case reproduces exactly with
//! [`forall_seeded`]. Coordinator invariants (routing, batching, staleness
//! accounting, reduction) are guarded with these properties in the
//! integration tests.

use crate::prng::Pcg64;

/// Generate one random case from a seeded generator.
pub trait Arbitrary: Sized {
    fn arbitrary(rng: &mut Pcg64) -> Self;
}

impl Arbitrary for usize {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        rng.below(1 << 16) as usize
    }
}

impl Arbitrary for u64 {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        rng.next_u64()
    }
}

impl Arbitrary for f64 {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        rng.normal() * 10.0
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        (A::arbitrary(rng), B::arbitrary(rng))
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary> Arbitrary for (A, B, C) {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        (A::arbitrary(rng), B::arbitrary(rng), C::arbitrary(rng))
    }
}

/// Run `prop` over `n` random cases derived from `seed`; panics with the
/// failing case seed on the first failure.
pub fn forall<T: Arbitrary + std::fmt::Debug>(
    name: &str,
    seed: u64,
    n: usize,
    mut prop: impl FnMut(&T) -> bool,
) {
    for i in 0..n {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Pcg64::new(case_seed);
        let case = T::arbitrary(&mut rng);
        if !prop(&case) {
            panic!(
                "property {name:?} failed on case #{i} (seed {case_seed:#x}): {case:?}\n\
                 reproduce with forall_seeded({case_seed:#x})"
            );
        }
    }
}

/// Reproduce a single failing case.
pub fn forall_seeded<T: Arbitrary + std::fmt::Debug>(case_seed: u64, prop: impl Fn(&T) -> bool) {
    let mut rng = Pcg64::new(case_seed);
    let case = T::arbitrary(&mut rng);
    assert!(prop(&case), "case (seed {case_seed:#x}): {case:?}");
}

/// Bounded value helper: map an arbitrary u64 into [lo, hi].
pub fn in_range(raw: u64, lo: usize, hi: usize) -> usize {
    assert!(hi >= lo);
    lo + (raw % (hi - lo + 1) as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall::<u64>("u64 is u64", 1, 64, |_| true);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failures() {
        forall::<u64>("always fails", 2, 8, |_| false);
    }

    #[test]
    fn in_range_bounds() {
        for raw in [0u64, 1, 99, u64::MAX] {
            let v = in_range(raw, 3, 9);
            assert!((3..=9).contains(&v));
        }
        assert_eq!(in_range(5, 4, 4), 4);
    }

    #[test]
    fn deterministic_cases() {
        let mut seen = Vec::new();
        forall::<(u64, u64)>("collect", 7, 4, |c| {
            seen.push(format!("{c:?}"));
            true
        });
        let first = seen.clone();
        seen.clear();
        forall::<(u64, u64)>("collect", 7, 4, |c| {
            seen.push(format!("{c:?}"));
            true
        });
        assert_eq!(first, seen);
    }
}

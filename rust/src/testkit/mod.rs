//! Mini property-testing harness (no proptest offline), plus shared
//! deterministic fixtures.
//!
//! [`forall`] runs a property over `n` seeded random cases; on failure it
//! reports the failing case seed so the case reproduces exactly with
//! [`forall_seeded`]. Coordinator invariants (routing, batching, staleness
//! accounting, reduction) are guarded with these properties in the
//! integration tests.
//!
//! [`DriftMember`] is the deterministic mock member the fault-injection
//! tests and the `coordinator_faults` example share: its dynamics
//! contract toward a bounded (id, step)-keyed drift attractor, so runs
//! converge to (nearly) the same final loss no matter how the exchange
//! misbehaved along the way — exactly the property the §2.2 scenarios
//! assert.

use crate::codistill::{Checkpoint, EvalStats, HostedMember, Member, StepStats};
use crate::prng::Pcg64;
use crate::runtime::{Tensor, TensorMap};
use std::sync::{Arc, Mutex};

/// Generate one random case from a seeded generator.
pub trait Arbitrary: Sized {
    fn arbitrary(rng: &mut Pcg64) -> Self;
}

impl Arbitrary for usize {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        rng.below(1 << 16) as usize
    }
}

impl Arbitrary for u64 {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        rng.next_u64()
    }
}

impl Arbitrary for f64 {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        rng.normal() * 10.0
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        (A::arbitrary(rng), B::arbitrary(rng))
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary> Arbitrary for (A, B, C) {
    fn arbitrary(rng: &mut Pcg64) -> Self {
        (A::arbitrary(rng), B::arbitrary(rng), C::arbitrary(rng))
    }
}

/// Run `prop` over `n` random cases derived from `seed`; panics with the
/// failing case seed on the first failure.
pub fn forall<T: Arbitrary + std::fmt::Debug>(
    name: &str,
    seed: u64,
    n: usize,
    mut prop: impl FnMut(&T) -> bool,
) {
    for i in 0..n {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Pcg64::new(case_seed);
        let case = T::arbitrary(&mut rng);
        if !prop(&case) {
            panic!(
                "property {name:?} failed on case #{i} (seed {case_seed:#x}): {case:?}\n\
                 reproduce with forall_seeded({case_seed:#x})"
            );
        }
    }
}

/// Reproduce a single failing case.
pub fn forall_seeded<T: Arbitrary + std::fmt::Debug>(case_seed: u64, prop: impl Fn(&T) -> bool) {
    let mut rng = Pcg64::new(case_seed);
    let case = T::arbitrary(&mut rng);
    assert!(prop(&case), "case (seed {case_seed:#x}): {case:?}");
}

/// Bounded value helper: map an arbitrary u64 into [lo, hi].
pub fn in_range(raw: u64, lo: usize, hi: usize) -> usize {
    assert!(hi >= lo);
    lo + (raw % (hi - lo + 1) as u64) as usize
}

// -------------------------------------------------- deterministic member

/// Observations a [`DriftMember`] records for assertions after the
/// coordinator has consumed the boxed member.
#[derive(Debug, Default)]
pub struct DriftProbe {
    /// Values adopted at bootstrap (mid-run join).
    pub bootstrapped: Option<Vec<f32>>,
    /// ψ weight passed to every train step, in order.
    pub distill_ws: Vec<f32>,
    /// Teacher-set size at every `set_teachers` call, in order.
    pub teacher_counts: Vec<usize>,
}

/// Deterministic member: parameters low-pass-filter an (id, step)-keyed
/// drift sequence and are pulled toward the installed teachers' mean, so
/// dynamics contract toward the same bounded attractor in every run and
/// fault-induced perturbations decay. Eval loss is `1 + mean|w|`.
pub struct DriftMember {
    id: usize,
    step: u64,
    params: TensorMap,
    teacher_mean: Option<Vec<f32>>,
    probe: Arc<Mutex<DriftProbe>>,
}

impl DriftMember {
    /// Parameter-vector width.
    pub const W: usize = 4;

    pub fn new(id: usize) -> Self {
        Self::with_probe(id, Arc::new(Mutex::new(DriftProbe::default())))
    }

    /// Share `probe` with a test that wants to inspect the member's
    /// interactions after the run.
    pub fn with_probe(id: usize, probe: Arc<Mutex<DriftProbe>>) -> Self {
        let init: Vec<f32> = (0..Self::W)
            .map(|k| 0.5 + id as f32 * 0.25 + 0.1 * k as f32)
            .collect();
        let mut params = TensorMap::new();
        params.insert("params.w", Tensor::f32(&[Self::W], init).unwrap());
        DriftMember {
            id,
            step: 0,
            params,
            teacher_mean: None,
            probe,
        }
    }

    /// Like [`DriftMember::new`], plus a `params.table` window of `elems`
    /// id-keyed constants that training never touches — the stand-in for
    /// an embedding table that rarely changes. Every publication after
    /// the first leaves its bytes (and so its content digest) identical,
    /// so a delta exchange must skip it; the OS-process harness asserts
    /// exactly that through the coordinator's delta accounting.
    pub fn with_frozen(id: usize, elems: usize) -> Self {
        Self::with_frozen_value(id, elems, 0.25 * (id as f32 + 1.0))
    }

    /// [`with_frozen`](Self::with_frozen) with an explicit table value.
    /// The lossy-exchange quality gate pins quantization bias on a value
    /// that is *off* the int8 power-of-two grid (the default
    /// `0.25·(id+1)` values all sit exactly on it, which would make the
    /// gate vacuous).
    pub fn with_frozen_value(id: usize, elems: usize, value: f32) -> Self {
        let mut m = Self::new(id);
        if elems > 0 {
            m.params.insert(
                "params.table",
                Tensor::f32(&[elems], vec![value; elems]).unwrap(),
            );
        }
        m
    }

    /// Current parameter vector.
    pub fn w(&self) -> Vec<f32> {
        self.params
            .get("params.w")
            .unwrap()
            .as_f32()
            .unwrap()
            .to_vec()
    }
}

impl Member for DriftMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> anyhow::Result<StepStats> {
        self.probe.lock().unwrap().distill_ws.push(distill_w);
        let teacher = self.teacher_mean.clone();
        let step = self.step;
        let id = self.id as u64;
        let w = self.params.get_mut("params.w")?.as_f32_mut()?;
        let mut distill_loss = 0.0f32;
        for (k, v) in w.iter_mut().enumerate() {
            let drift = (((step * 7 + id * 13 + k as u64 * 5) % 11) as f32) * 0.02 - 0.1;
            *v = *v * (1.0 - lr) + lr * drift;
            if distill_w > 0.0 {
                if let Some(t) = &teacher {
                    let pull = t[k] - *v;
                    *v += distill_w * lr * 0.5 * pull;
                    distill_loss += pull * pull;
                }
            }
        }
        self.step += 1;
        let loss = w.iter().map(|v| v.abs()).sum::<f32>() / Self::W as f32;
        Ok(StepStats {
            step: self.step,
            loss,
            distill_loss,
        })
    }

    fn snapshot(&self) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint::new(self.id, self.step, self.params.clone()))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> anyhow::Result<()> {
        self.probe.lock().unwrap().teacher_counts.push(peers.len());
        let mut mean = vec![0.0f32; Self::W];
        for p in &peers {
            for (m, v) in mean.iter_mut().zip(p.flat().view("params.w")?) {
                *m += *v;
            }
        }
        for m in &mut mean {
            *m /= peers.len() as f32;
        }
        self.teacher_mean = Some(mean);
        Ok(())
    }

    fn bootstrap(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        let vals = ck.flat().view("params.w")?.to_vec();
        self.params
            .get_mut("params.w")?
            .as_f32_mut()?
            .copy_from_slice(&vals);
        self.probe.lock().unwrap().bootstrapped = Some(vals);
        Ok(())
    }

    fn evaluate(&mut self) -> anyhow::Result<EvalStats> {
        let loss =
            1.0 + self.w().iter().map(|v| v.abs() as f64).sum::<f64>() / Self::W as f64;
        Ok(EvalStats {
            loss,
            accuracy: None,
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.params
    }
}

/// A hosted fleet of `n` [`DriftMember`]s with global ids `0..n`, each
/// publishing every `publish_interval` local steps — the cheap
/// O(100)-member cohort the churn-scenario tests drive through a
/// [`Coordinator`](crate::codistill::Coordinator). Overlay join/downtime
/// schedules with `CompiledScenario::apply` or the `HostedMember`
/// builders.
pub fn drift_fleet(n: usize, publish_interval: u64) -> Vec<HostedMember> {
    (0..n)
        .map(|i| HostedMember::new(i, Box::new(DriftMember::new(i)), publish_interval))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_fleet_ids_and_cadence() {
        let fleet = drift_fleet(100, 10);
        assert_eq!(fleet.len(), 100);
        assert!(fleet.iter().enumerate().all(|(i, h)| h.id == i));
        assert!(fleet
            .iter()
            .all(|h| h.publish_interval == 10 && h.join_delay == 0 && h.downtimes.is_empty()));
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall::<u64>("u64 is u64", 1, 64, |_| true);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failures() {
        forall::<u64>("always fails", 2, 8, |_| false);
    }

    #[test]
    fn in_range_bounds() {
        for raw in [0u64, 1, 99, u64::MAX] {
            let v = in_range(raw, 3, 9);
            assert!((3..=9).contains(&v));
        }
        assert_eq!(in_range(5, 4, 4), 4);
    }

    #[test]
    fn deterministic_cases() {
        let mut seen = Vec::new();
        forall::<(u64, u64)>("collect", 7, 4, |c| {
            seen.push(format!("{c:?}"));
            true
        });
        let first = seen.clone();
        seen.clear();
        forall::<(u64, u64)>("collect", 7, 4, |c| {
            seen.push(format!("{c:?}"));
            true
        });
        assert_eq!(first, seen);
    }
}

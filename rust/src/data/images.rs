//! Synthetic image classification data — the ImageNet stand-in (Fig 3).
//!
//! Ten class prototypes are sampled once from the dataset seed; an example
//! is `normalize(prototype[class] + noise · N(0,1))` with per-example noise
//! level jittered so the Bayes error is nonzero and the accuracy curve has
//! the paper's shape: fast rise, then a long slow tail toward a <100%
//! plateau. Class priors are uniform.

use crate::prng::{derive_seed, Pcg64};
use crate::runtime::Tensor;
use anyhow::Result;

pub struct ImageBatch {
    /// `[B, S, S, C]` f32.
    pub images: Tensor,
    /// `[B]` i32 class ids.
    pub labels: Tensor,
}

pub struct ImageGen {
    size: usize,
    channels: usize,
    classes: usize,
    /// `[classes, S*S*C]` prototype pixels.
    prototypes: Vec<Vec<f32>>,
    noise: f64,
    rng: Pcg64,
}

impl ImageGen {
    pub fn new(seed: u64, stream: u64, size: usize, channels: usize, classes: usize) -> Self {
        let mut proto_rng = Pcg64::new(derive_seed(seed, "images-prototypes"));
        let dim = size * size * channels;
        let prototypes: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..dim).map(|_| proto_rng.normal() as f32).collect())
            .collect();
        ImageGen {
            size,
            channels,
            classes,
            prototypes,
            noise: 2.0,
            rng: Pcg64::new(derive_seed(seed, &format!("images-stream-{stream}"))),
        }
    }

    /// Override the noise level (signal-to-noise knob for the accuracy
    /// plateau; default 2.0 targets a ~75-85% plateau like the paper's
    /// 75% top-1 operating point).
    pub fn with_noise(mut self, noise: f64) -> Self {
        self.noise = noise;
        self
    }

    pub fn next_batch(&mut self, b: usize) -> Result<ImageBatch> {
        let dim = self.size * self.size * self.channels;
        let mut images = Vec::with_capacity(b * dim);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let class = self.rng.below(self.classes as u64) as usize;
            // Jitter per-example noise so some examples are genuinely hard.
            let sigma = self.noise * self.rng.uniform_range(0.5, 1.5);
            let proto = &self.prototypes[class];
            for &p in proto.iter() {
                images.push(p + (self.rng.normal() * sigma) as f32);
            }
            labels.push(class as i32);
        }
        // Per-image standardization (like ImageNet preprocessing).
        for img in images.chunks_mut(dim) {
            let mean: f32 = img.iter().sum::<f32>() / dim as f32;
            let var: f32 =
                img.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / dim as f32;
            let rstd = 1.0 / (var.sqrt() + 1e-6);
            for x in img.iter_mut() {
                *x = (*x - mean) * rstd;
            }
        }
        Ok(ImageBatch {
            images: Tensor::f32(&[b, self.size, self.size, self.channels], images)?,
            labels: Tensor::i32(&[b], labels)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = ImageGen::new(1, 0, 8, 3, 10);
        let mut b = ImageGen::new(1, 0, 8, 3, 10);
        let ba = a.next_batch(4).unwrap();
        let bb = b.next_batch(4).unwrap();
        assert_eq!(ba.images.as_f32().unwrap(), bb.images.as_f32().unwrap());
        assert_eq!(ba.labels.as_i32().unwrap(), bb.labels.as_i32().unwrap());
    }

    #[test]
    fn shapes_and_label_range() {
        let mut g = ImageGen::new(2, 0, 16, 3, 10);
        let b = g.next_batch(32).unwrap();
        assert_eq!(b.images.shape(), &[32, 16, 16, 3]);
        assert_eq!(b.labels.shape(), &[32]);
        assert!(b.labels.as_i32().unwrap().iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn images_standardized() {
        let mut g = ImageGen::new(3, 0, 8, 3, 10);
        let b = g.next_batch(4).unwrap();
        let data = b.images.as_f32().unwrap();
        let dim = 8 * 8 * 3;
        for img in data.chunks(dim) {
            let mean: f32 = img.iter().sum::<f32>() / dim as f32;
            assert!(mean.abs() < 1e-4);
        }
    }

    #[test]
    fn nearest_prototype_beats_chance() {
        // The generator must be learnable: nearest-prototype classification
        // on noisy examples should beat 10% by a wide margin.
        let mut g = ImageGen::new(4, 0, 8, 3, 10);
        let protos = g.prototypes.clone();
        let b = g.next_batch(200).unwrap();
        let data = b.images.as_f32().unwrap();
        let labels = b.labels.as_i32().unwrap();
        let dim = 8 * 8 * 3;
        let mut correct = 0;
        for (img, &label) in data.chunks(dim).zip(labels.iter()) {
            let mut best = (f32::INFINITY, 0usize);
            for (c, p) in protos.iter().enumerate() {
                // cosine distance is immune to the standardization scale
                let dot: f32 = img.iter().zip(p.iter()).map(|(a, b)| a * b).sum();
                let na: f32 = img.iter().map(|a| a * a).sum::<f32>().sqrt();
                let nb: f32 = p.iter().map(|b| b * b).sum::<f32>().sqrt();
                let d = 1.0 - dot / (na * nb + 1e-9);
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == label as usize {
                correct += 1;
            }
        }
        assert!(correct > 100, "nearest-prototype acc {}/200", correct);
    }
}

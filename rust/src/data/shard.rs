//! Shard assignment: which data streams feed which (group, worker, row).
//!
//! The paper's codistillation protocol trains each group "on a locally
//! available subset of the training data" (§2.1). Fig 2b's control arm
//! forces both groups onto the *same* subset to show that the gains come
//! from information about unseen data flowing through teacher predictions.
//!
//! A [`ShardPlan`] deterministically maps every batch row of every group to
//! a stream id. Stream ids are globally unique in [`ShardMode::Disjoint`]
//! and shared across groups in [`ShardMode::SameData`].

/// How groups' data shards relate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Every group sees its own disjoint slice (the paper's main setup).
    Disjoint,
    /// All groups see identical data (Fig 2b control).
    SameData,
}

impl ShardMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "disjoint" => Some(ShardMode::Disjoint),
            "same" | "same-data" => Some(ShardMode::SameData),
            _ => None,
        }
    }
}

/// Deterministic stream-id assignment.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub n_groups: usize,
    pub rows_per_group: usize,
    pub mode: ShardMode,
}

impl ShardPlan {
    pub fn new(n_groups: usize, rows_per_group: usize, mode: ShardMode) -> Self {
        assert!(n_groups > 0 && rows_per_group > 0);
        ShardPlan {
            n_groups,
            rows_per_group,
            mode,
        }
    }

    /// Stream ids for one group's batch rows.
    pub fn group_streams(&self, group: usize) -> Vec<u64> {
        assert!(group < self.n_groups, "group {group} out of range");
        let base = match self.mode {
            ShardMode::Disjoint => (group * self.rows_per_group) as u64,
            ShardMode::SameData => 0,
        };
        (0..self.rows_per_group as u64).map(|r| base + r).collect()
    }

    /// Stream ids for the validation set: a reserved range that never
    /// overlaps any group's training streams.
    pub fn validation_streams(&self, rows: usize) -> Vec<u64> {
        let base = (self.n_groups * self.rows_per_group) as u64 + 1_000_000;
        (0..rows as u64).map(|r| base + r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn disjoint_groups_do_not_overlap() {
        let plan = ShardPlan::new(4, 8, ShardMode::Disjoint);
        let mut seen = HashSet::new();
        for g in 0..4 {
            for s in plan.group_streams(g) {
                assert!(seen.insert(s), "stream {s} duplicated");
            }
        }
        assert_eq!(seen.len(), 32);
    }

    #[test]
    fn same_data_groups_are_identical() {
        let plan = ShardPlan::new(3, 16, ShardMode::SameData);
        let a = plan.group_streams(0);
        let b = plan.group_streams(2);
        assert_eq!(a, b);
    }

    #[test]
    fn validation_never_overlaps_training() {
        let plan = ShardPlan::new(2, 64, ShardMode::Disjoint);
        let train: HashSet<u64> = (0..2).flat_map(|g| plan.group_streams(g)).collect();
        for v in plan.validation_streams(64) {
            assert!(!train.contains(&v));
        }
    }

    #[test]
    #[should_panic]
    fn group_out_of_range_panics() {
        ShardPlan::new(2, 4, ShardMode::Disjoint).group_streams(2);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(ShardMode::parse("disjoint"), Some(ShardMode::Disjoint));
        assert_eq!(ShardMode::parse("same"), Some(ShardMode::SameData));
        assert_eq!(ShardMode::parse("nope"), None);
    }
}

//! Data substrates.
//!
//! The paper trains on Common Crawl (673B word pieces), ImageNet, and the
//! Criteo click logs — none of which are available here, so each is
//! replaced by a deterministic synthetic generator that exercises the same
//! code paths and preserves the statistics the experiments depend on
//! (DESIGN.md §4). Every generator is an infinite, seed-addressed stream:
//! "never revisits data" holds just as it does for the paper's corpus.

pub mod corpus;
pub mod criteo;
pub mod images;
pub mod shard;

pub use corpus::{Batcher, CorpusConfig, TokenStream};
pub use criteo::{CriteoBatch, CriteoGen};
pub use images::{ImageBatch, ImageGen};
pub use shard::{ShardMode, ShardPlan};

//! Synthetic click-through-rate data — the Criteo stand-in (Table 1).
//!
//! Matches the Criteo Display Ad Challenge schema: 13 integer features and
//! 26 categorical features per example, binary label. Labels come from a
//! fixed ground-truth model (sampled once from the dataset seed):
//!
//!   logit = Σ w_d·log1p(x_d) + Σ w_c[field, bucket] + Σ crosses + b
//!   y ~ Bernoulli(sigmoid(logit))
//!
//! with sparse pairwise crosses between categorical fields — enough
//! structure that an MLP beats logistic regression, and enough noise that
//! retrains genuinely disagree (which is the phenomenon Table 1 measures).
//!
//! Integer features are drawn lognormal (heavy-tailed counts, like the
//! real dataset) and presented to the model as `log1p`, matching standard
//! Criteo preprocessing. Categorical buckets are Zipfian per field.

use crate::prng::{derive_seed, Pcg64, Zipf};
use crate::runtime::Tensor;
use anyhow::Result;

pub const N_DENSE: usize = 13;
pub const N_CAT: usize = 26;

/// One batch, already in model layout.
pub struct CriteoBatch {
    /// `[B, 13]` f32, log1p-normalized.
    pub dense: Tensor,
    /// `[B, 26]` i32 in `[0, buckets)`.
    pub cat_idx: Tensor,
    /// `[B]` i32 in `{0, 1}`.
    pub labels: Tensor,
}

/// Ground-truth CTR model + example generator.
pub struct CriteoGen {
    buckets: usize,
    w_dense: Vec<f64>,
    /// Per-field per-bucket weight, `[26 * buckets]`.
    w_cat: Vec<f64>,
    /// Sparse crosses: (field_a, field_b, hash-salt, weight).
    crosses: Vec<(usize, usize, u64, f64)>,
    bias: f64,
    /// Per-field bucket popularity.
    zipf: Zipf,
    rng: Pcg64,
}

impl CriteoGen {
    /// `seed` fixes the ground-truth model AND the example stream;
    /// `stream` separates train/validation/worker streams over the same
    /// ground truth.
    pub fn new(seed: u64, stream: u64, buckets: usize) -> Self {
        let mut truth_rng = Pcg64::new(derive_seed(seed, "criteo-truth"));
        let w_dense: Vec<f64> = (0..N_DENSE).map(|_| truth_rng.normal() * 0.3).collect();
        let w_cat: Vec<f64> = (0..N_CAT * buckets)
            .map(|_| truth_rng.normal() * 0.25)
            .collect();
        let mut crosses = Vec::new();
        for _ in 0..24 {
            let a = truth_rng.below(N_CAT as u64) as usize;
            let b = truth_rng.below(N_CAT as u64) as usize;
            let salt = truth_rng.next_u64();
            let w = truth_rng.normal() * 0.4;
            crosses.push((a, b, salt, w));
        }
        CriteoGen {
            buckets,
            w_dense,
            w_cat,
            crosses,
            bias: -1.2, // base CTR well below 50%, like real ad data
            zipf: Zipf::new(buckets, 1.1),
            rng: Pcg64::new(derive_seed(seed, &format!("criteo-stream-{stream}"))),
        }
    }

    fn hash2(a: usize, b: usize, salt: u64) -> u64 {
        let mut h = salt ^ 0x9e3779b97f4a7c15;
        h = h.wrapping_mul(0x100000001b3) ^ (a as u64).wrapping_mul(0x9e3779b1);
        h = h.wrapping_mul(0x100000001b3) ^ (b as u64).wrapping_mul(0x85ebca6b);
        h ^ (h >> 29)
    }

    /// Generate one example: (raw dense counts, bucket ids, label, true p).
    fn example(&mut self) -> ([f64; N_DENSE], [usize; N_CAT], i32, f64) {
        let mut dense = [0.0f64; N_DENSE];
        for d in dense.iter_mut() {
            *d = self.rng.lognormal(1.0, 1.5).floor();
        }
        let mut cats = [0usize; N_CAT];
        for c in cats.iter_mut() {
            *c = self.zipf.sample(&mut self.rng);
        }
        let mut logit = self.bias;
        for (i, &x) in dense.iter().enumerate() {
            logit += self.w_dense[i] * (1.0 + x).ln();
        }
        for (f, &bkt) in cats.iter().enumerate() {
            logit += self.w_cat[f * self.buckets + bkt];
        }
        for &(a, b, salt, w) in &self.crosses {
            let h = Self::hash2(cats[a], cats[b], salt);
            // cross fires on ~1/8 of bucket pairs
            if h % 8 == 0 {
                logit += w;
            }
        }
        let p = 1.0 / (1.0 + (-logit).exp());
        let y = self.rng.bernoulli(p) as i32;
        (dense, cats, y, p)
    }

    /// Next batch of `b` examples in model layout.
    pub fn next_batch(&mut self, b: usize) -> Result<CriteoBatch> {
        let mut dense = Vec::with_capacity(b * N_DENSE);
        let mut cat = Vec::with_capacity(b * N_CAT);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let (d, c, y, _) = self.example();
            dense.extend(d.iter().map(|&x| (1.0 + x).ln() as f32));
            cat.extend(c.iter().map(|&x| x as i32));
            labels.push(y);
        }
        Ok(CriteoBatch {
            dense: Tensor::f32(&[b, N_DENSE], dense)?,
            cat_idx: Tensor::i32(&[b, N_CAT], cat)?,
            labels: Tensor::i32(&[b], labels)?,
        })
    }

    /// Empirical base CTR over n samples (diagnostics).
    pub fn base_rate(&mut self, n: usize) -> f64 {
        let mut hits = 0usize;
        for _ in 0..n {
            let (_, _, y, _) = self.example();
            hits += y as usize;
        }
        hits as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seeds() {
        let mut a = CriteoGen::new(1, 0, 100);
        let mut b = CriteoGen::new(1, 0, 100);
        let ba = a.next_batch(16).unwrap();
        let bb = b.next_batch(16).unwrap();
        assert_eq!(ba.dense.as_f32().unwrap(), bb.dense.as_f32().unwrap());
        assert_eq!(ba.cat_idx.as_i32().unwrap(), bb.cat_idx.as_i32().unwrap());
        assert_eq!(ba.labels.as_i32().unwrap(), bb.labels.as_i32().unwrap());
    }

    #[test]
    fn streams_differ_but_share_truth() {
        // Different streams -> different examples; same truth means the
        // base rate is similar.
        let mut a = CriteoGen::new(1, 0, 100);
        let mut b = CriteoGen::new(1, 1, 100);
        let ba = a.next_batch(16).unwrap();
        let bb = b.next_batch(16).unwrap();
        assert_ne!(ba.dense.as_f32().unwrap(), bb.dense.as_f32().unwrap());
        let ra = a.base_rate(4000);
        let rb = b.base_rate(4000);
        assert!((ra - rb).abs() < 0.05, "{ra} vs {rb}");
    }

    #[test]
    fn label_rate_reasonable() {
        let mut g = CriteoGen::new(3, 0, 1000);
        let r = g.base_rate(5000);
        assert!((0.05..0.8).contains(&r), "base rate {r}");
    }

    #[test]
    fn bucket_ids_in_range_and_zipfian() {
        let mut g = CriteoGen::new(5, 0, 50);
        let batch = g.next_batch(256).unwrap();
        let ids = batch.cat_idx.as_i32().unwrap();
        assert!(ids.iter().all(|&i| (0..50).contains(&i)));
        let zero_frac = ids.iter().filter(|&&i| i == 0).count() as f64 / ids.len() as f64;
        assert!(zero_frac > 0.1, "bucket 0 should be popular, got {zero_frac}");
    }

    #[test]
    fn dense_features_lognormalized() {
        let mut g = CriteoGen::new(7, 0, 100);
        let batch = g.next_batch(64).unwrap();
        let d = batch.dense.as_f32().unwrap();
        assert!(d.iter().all(|&x| (0.0..20.0).contains(&x)));
    }
}

//! The Criteo CTR member (paper §3.1 + Table 1).
//!
//! Stateless feed-forward model: simpler than the LM member (no RNN state
//! to thread), but it adds the churn-measurement surface — predictions on
//! a *fixed* validation set, comparable across independent retrains.

use crate::codistill::{Checkpoint, EvalStats, Member, StepStats};
use crate::data::criteo::{CriteoBatch, CriteoGen};
use crate::models::lm::{run_mapped, zeros_for_prefix};
use crate::runtime::{Bundle, Executable, Tensor, TensorMap};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Fixed validation set, shared across retrains (Arc so every member of a
/// churn experiment scores the identical examples).
pub struct CriteoValSet {
    pub batches: Vec<CriteoBatch>,
}

impl CriteoValSet {
    /// Build from a dedicated validation stream.
    pub fn generate(seed: u64, stream: u64, buckets: usize, batch: usize, n: usize) -> Result<Arc<Self>> {
        let mut gen = CriteoGen::new(seed, stream, buckets);
        let batches = (0..n)
            .map(|_| gen.next_batch(batch))
            .collect::<Result<Vec<_>>>()?;
        Ok(Arc::new(CriteoValSet { batches }))
    }

    pub fn examples(&self) -> usize {
        self.batches
            .iter()
            .map(|b| b.labels.numel())
            .sum()
    }
}

pub struct CriteoMember {
    train_step: Arc<Executable>,
    predict: Arc<Executable>,
    eval_exe: Arc<Executable>,
    vars: TensorMap,
    teachers: Vec<TensorMap>,
    gen: CriteoGen,
    val: Arc<CriteoValSet>,
    batch: usize,
    step: u64,
}

impl CriteoMember {
    /// `init_seed` differentiates retrains; `stream` is this member's data
    /// shard (disjoint across codistilling members, per the paper).
    pub fn new(
        bundle: &Bundle,
        data_seed: u64,
        stream: u64,
        init_seed: i32,
        val: Arc<CriteoValSet>,
    ) -> Result<Self> {
        let train_step = bundle.exe("train_step")?;
        let predict = bundle.exe("predict")?;
        let eval_exe = bundle.exe("eval")?;
        let buckets = bundle.meta_usize("buckets")?;
        let batch = bundle.meta_usize("batch")?;
        let init = bundle.exe("init")?;
        let outs = init.run(&[&Tensor::scalar_i32(init_seed)])?;
        let mut vars = TensorMap::from_outputs(init.spec(), outs)?;
        vars.merge(zeros_for_prefix(train_step.spec(), "opt."));
        // Adagrad accumulator starts at 0.1 (model_criteo.init_opt).
        for idx in train_step.spec().inputs_with_prefix("opt.") {
            let name = train_step.spec().inputs[idx].name.clone();
            let t = vars.get_mut(&name)?;
            if let Ok(d) = t.as_f32_mut() {
                for v in d.iter_mut() {
                    *v = 0.1;
                }
            }
        }
        Ok(CriteoMember {
            train_step,
            predict,
            eval_exe,
            vars,
            teachers: Vec::new(),
            gen: CriteoGen::new(data_seed, stream, buckets),
            val,
            batch,
            step: 0,
        })
    }

    /// Teacher CTR probabilities on a batch: mean over stale peers.
    fn teacher_p(&mut self, batch: &CriteoBatch) -> Result<Tensor> {
        let mut acc: Option<Tensor> = None;
        for t in &self.teachers {
            let mut extra = TensorMap::new();
            extra.insert("dense", batch.dense.clone());
            extra.insert("cat_idx", batch.cat_idx.clone());
            let outs = run_mapped(&self.predict, t, &extra)?;
            let p = outs.get("probs")?.clone();
            match &mut acc {
                None => acc = Some(p),
                Some(a) => a.add_assign(&p)?,
            }
        }
        let mut p = acc.context("no teachers")?;
        if self.teachers.len() > 1 {
            p.scale(1.0 / self.teachers.len() as f32)?;
        }
        Ok(p)
    }

    /// Predictions over the fixed validation set — the Table 1 churn
    /// surface. Returns one probability per validation example.
    pub fn val_predictions(&self) -> Result<Vec<f32>> {
        let mut preds = Vec::with_capacity(self.val.examples());
        for b in &self.val.batches {
            let mut extra = TensorMap::new();
            extra.insert("dense", b.dense.clone());
            extra.insert("cat_idx", b.cat_idx.clone());
            let outs = run_mapped(&self.predict, &self.vars, &extra)?;
            preds.extend_from_slice(outs.get("probs")?.as_f32()?);
        }
        Ok(preds)
    }

    pub fn val_set(&self) -> &Arc<CriteoValSet> {
        &self.val
    }
}

impl Member for CriteoMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> Result<StepStats> {
        let batch = self.gen.next_batch(self.batch)?;
        let (teacher_p, w) = if distill_w > 0.0 && !self.teachers.is_empty() {
            (self.teacher_p(&batch)?, distill_w)
        } else {
            (Tensor::full_f32(&[self.batch], 0.5), 0.0)
        };
        let mut extra = TensorMap::new();
        extra.insert("dense", batch.dense);
        extra.insert("cat_idx", batch.cat_idx);
        extra.insert("labels", batch.labels);
        extra.insert("teacher_p", teacher_p);
        extra.insert("distill_w", Tensor::scalar_f32(w));
        extra.insert("lr", Tensor::scalar_f32(lr));
        let outs = run_mapped(&self.train_step, &self.vars, &extra)?;
        let loss = outs.get("loss")?.item_f32()?;
        let dloss = outs.get("distill_loss")?.item_f32()?;
        self.vars.adopt_prefix(&outs, "params.", "params.");
        self.vars.adopt_prefix(&outs, "opt.", "opt.");
        self.step += 1;
        Ok(StepStats {
            step: self.step,
            loss,
            distill_loss: dloss,
        })
    }

    fn snapshot(&self) -> Result<Checkpoint> {
        let mut params = TensorMap::new();
        params.adopt_prefix(&self.vars, "params.", "params.");
        Ok(Checkpoint::new(0, self.step, params))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> Result<()> {
        // Refresh each teacher in place when the peer's plane lines up
        // with the installed storage; rebuild otherwise.
        let mut old = std::mem::take(&mut self.teachers).into_iter();
        self.teachers = peers
            .into_iter()
            .map(|c| match old.next() {
                Some(prev) => c.refresh_params(prev),
                None => Ok(c.params()),
            })
            .collect::<Result<_>>()?;
        Ok(())
    }

    fn evaluate(&mut self) -> Result<EvalStats> {
        let mut sum = 0.0f64;
        let mut count = 0.0f64;
        for b in &self.val.batches {
            let mut extra = TensorMap::new();
            extra.insert("dense", b.dense.clone());
            extra.insert("cat_idx", b.cat_idx.clone());
            extra.insert("labels", b.labels.clone());
            let outs = run_mapped(&self.eval_exe, &self.vars, &extra)?;
            sum += outs.get("sum_loss")?.item_f32()? as f64;
            count += outs.get("count")?.item_f32()? as f64;
        }
        Ok(EvalStats {
            loss: sum / count.max(1.0),
            accuracy: None,
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.vars
    }
}

//! Model-family trainers (codistillation [`Member`](crate::codistill::Member)
//! implementations) built on the artifact bundles.
//!
//! * [`lm`] — the LayerNorm-LSTM language model (Common Crawl experiments):
//!   fused large-batch member + real allreduce worker group.
//! * [`criteo`] — the CTR DNN (Table 1 churn experiments).
//! * [`images`] — the convnet (Fig 3 / ImageNet experiments).
//! * [`mock`] — the deterministic hash-tap forward the serving tier uses
//!   in mock mode (no artifacts/XLA; pairs with `testkit::DriftMember`).

pub mod criteo;
pub mod images;
pub mod lm;
pub mod mock;

pub use criteo::CriteoMember;
pub use images::ImagesMember;
pub use lm::{LmMember, LmSyncGroup, SmoothingMode};
pub use mock::MockForward;

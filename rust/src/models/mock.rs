//! Deterministic mock forward path for serving without artifacts/XLA.
//!
//! [`MockForward`] is the serving-tier counterpart of
//! [`testkit::DriftMember`](crate::testkit::DriftMember): a cheap, fully
//! deterministic "model" whose predictions are a pure function of
//! (plane bytes, feature ids, salt). Each feature id seeds a splitmix64
//! walk that taps four parameter positions in the installed plane and
//! squashes their weighted sum into (0, 1) with a rational sigmoid —
//! no `exp`, no tables, no allocation beyond the output vector.
//!
//! Two properties make it the right fixture for the hot-swap tests:
//!
//! * **Plane-sensitive**: any change to a tapped parameter changes the
//!   prediction, so swapping in a fresh checkpoint visibly moves the
//!   outputs (nonzero churn across swaps).
//! * **Bit-reproducible**: same plane + same features ⇒ bit-identical
//!   probabilities, so a response can be re-derived offline from the
//!   retained checkpoint and compared exactly — the "no torn plane"
//!   check in `tests/serve_hotswap.rs`, and the serving analogue of the
//!   paper's §3.5 prediction-churn measurements.

use crate::codistill::serve::ServingModel;
use crate::codistill::Checkpoint;
use anyhow::{bail, Result};

/// splitmix64 finalizer: one step of the id-keyed tap walk.
fn mix(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// Deterministic hash-tap forward over an installed plane.
#[derive(Debug, Clone)]
pub struct MockForward {
    /// Varies the tap pattern between logically distinct deployments.
    pub salt: u64,
}

impl MockForward {
    /// Taps per feature: enough that every prediction mixes several
    /// plane positions, few enough to stay trivially cheap.
    pub const TAPS: usize = 4;

    pub fn new() -> Self {
        MockForward { salt: 0 }
    }

    pub fn with_salt(salt: u64) -> Self {
        MockForward { salt }
    }

    /// Probability for each feature id against `ckpt`'s plane. Pure:
    /// same (salt, plane, features) ⇒ bit-identical output.
    pub fn probs(&self, ckpt: &Checkpoint, features: &[u64]) -> Result<Vec<f32>> {
        let data = ckpt.flat().data();
        if data.is_empty() {
            bail!("mock forward over an empty plane (member {})", ckpt.member);
        }
        let n = data.len() as u64;
        let mut out = Vec::with_capacity(features.len());
        for &f in features {
            let mut h = mix(f ^ self.salt ^ 0x9e37_79b9_7f4a_7c15);
            let mut acc = 0.0f32;
            for tap in 0..Self::TAPS {
                h = mix(h);
                let idx = (h % n) as usize;
                // alternating-sign taper so taps neither cancel nor blow up
                let w = if tap % 2 == 0 { 1.0 } else { -0.5 } / (1 + tap) as f32;
                acc += data[idx] * w;
            }
            // rational sigmoid: monotone, (0,1), exactly reproducible
            out.push(0.5 + 0.5 * (acc / (1.0 + acc.abs())));
        }
        Ok(out)
    }
}

impl Default for MockForward {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingModel for MockForward {
    fn predict(&self, ckpt: &Checkpoint, features: &[u64]) -> Result<Vec<f32>> {
        self.probs(ckpt, features)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codistill::Member;
    use crate::testkit::DriftMember;

    fn snap(id: usize, steps: u64) -> Checkpoint {
        let mut m = DriftMember::new(id);
        for _ in 0..steps {
            m.train_step(0.0, 0.1).unwrap();
        }
        m.snapshot().unwrap()
    }

    #[test]
    fn deterministic_and_bounded() {
        let ck = snap(0, 5);
        let fwd = MockForward::new();
        let feats: Vec<u64> = (0..64).collect();
        let a = fwd.probs(&ck, &feats).unwrap();
        let b = fwd.probs(&ck, &feats).unwrap();
        assert_eq!(a, b, "same plane + features must be bit-identical");
        assert!(a.iter().all(|p| (0.0..=1.0).contains(p)));
        assert_eq!(a.len(), feats.len());
    }

    #[test]
    fn sensitive_to_plane_changes() {
        let fwd = MockForward::new();
        let feats: Vec<u64> = (0..32).collect();
        let a = fwd.probs(&snap(0, 2), &feats).unwrap();
        let b = fwd.probs(&snap(0, 10), &feats).unwrap();
        assert_ne!(a, b, "training between snapshots must move predictions");
    }

    #[test]
    fn salt_varies_the_taps() {
        let ck = snap(1, 3);
        let feats: Vec<u64> = (0..32).collect();
        let a = MockForward::with_salt(1).probs(&ck, &feats).unwrap();
        let b = MockForward::with_salt(2).probs(&ck, &feats).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn empty_plane_errors() {
        use crate::runtime::TensorMap;
        let ck = Checkpoint::new(0, 0, TensorMap::new());
        assert!(MockForward::new().probs(&ck, &[1, 2]).is_err());
    }
}

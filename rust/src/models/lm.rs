//! The language-model codistillation member (the paper's Common Crawl
//! workload).
//!
//! Two flavours share all the plumbing:
//!
//! * [`LmMember`] — a whole sync-SGD group simulated as one fused
//!   large-batch `train_step` (mathematically identical: the mean gradient
//!   over W shards of size b equals the gradient of one W·b batch).
//! * [`LmSyncGroup`] — the explicit data-parallel path: W workers, each
//!   running the per-worker `grad` executable on its own shard (in
//!   parallel threads), reduced with [`allreduce_mean`], applied with the
//!   `apply` executable. Used to validate the fused equivalence and to
//!   measure coordinator overhead.
//!
//! Teacher handling follows the paper: a member holds stale copies of its
//! peers' weights (refreshed by the orchestrator on the reload interval)
//! and computes teacher predictions *locally* on its own next batch with
//! the `predict` executable. The teacher's RNN hidden state on this
//! member's streams is owned by this member — stale weights, fresh state.

use crate::codistill::{Checkpoint, EvalStats, Member, StepStats};
use crate::data::corpus::{Batcher, CorpusConfig};
use crate::runtime::flat::FlatLayout;
use crate::runtime::{Bundle, Executable, Tensor, TensorMap};
use crate::sgd::allreduce::{allreduce_mean, allreduce_mean_flat, ReduceStrategy};
use anyhow::{bail, Context, Result};
use std::cell::OnceCell;
use std::sync::{Arc, Mutex};

/// Fig 2a label-smoothing baselines: ψ against a fixed distribution.
#[derive(Debug, Clone)]
pub enum SmoothingMode {
    /// Plain codistillation (teacher = stale peers).
    None,
    /// ψ against the uniform distribution (confidence penalty baseline).
    Uniform,
    /// ψ against the corpus unigram distribution.
    Unigram(Vec<f32>),
}

/// Static dims read from the bundle.
#[derive(Debug, Clone, Copy)]
pub struct LmDims {
    pub vocab: usize,
    pub batch: usize,
    pub unroll: usize,
}

impl LmDims {
    pub fn from_bundle(bundle: &Bundle) -> Result<Self> {
        Ok(LmDims {
            vocab: bundle.meta_usize("vocab")?,
            batch: bundle.meta_usize("batch")?,
            unroll: bundle.meta_usize("unroll")?,
        })
    }
}

/// A stale teacher copy + its RNN state on this member's streams.
struct Teacher {
    /// `params.*` of the stale peer.
    params: TensorMap,
    /// `state.*` threaded through `predict` calls.
    state: TensorMap,
    /// Step the checkpoint was published at (staleness accounting).
    ckpt_step: u64,
    /// Flat plane the params were scattered from. When the next reload
    /// speaks the same plane, new weights scatter into the existing
    /// tensor storage — no allocation on the exchange cadence.
    plane: Arc<FlatLayout>,
}

/// Shared plumbing for both flavours.
struct LmCore {
    dims: LmDims,
    predict: Arc<Executable>,
    eval_exe: Arc<Executable>,
    /// Training variables: `params.*`, `opt.*`, `state.*`.
    vars: TensorMap,
    teachers: Vec<Teacher>,
    smoothing: SmoothingMode,
    batcher: Batcher,
    val_batcher: Batcher,
    val_state: TensorMap,
    val_batches: usize,
    zero_probs: Tensor,
    smooth_probs: Option<Tensor>,
    /// Pre-converted literals for step-invariant inputs (zero / smoothing
    /// distributions) — §Perf constant-input caching.
    const_lits: std::collections::HashMap<String, xla::Literal>,
    /// Flat plane of this member's own `params.*` leaves, computed on the
    /// first snapshot and reused by every publication (the checkpoint
    /// exchange never re-derives name→offset maps).
    snapshot_plane: OnceCell<Arc<FlatLayout>>,
    step: u64,
    /// Cumulative teacher forward passes (perf accounting).
    teacher_fwd: u64,
}

pub fn zeros_for_prefix(spec: &crate::runtime::Spec, prefix: &str) -> TensorMap {
    let mut m = TensorMap::new();
    for ts in spec.inputs_under(prefix) {
        m.insert(ts.name.clone(), Tensor::zeros(ts));
    }
    m
}

pub fn run_mapped(
    exe: &Executable,
    joined: &TensorMap,
    extra: &TensorMap,
) -> Result<TensorMap> {
    run_mapped_cached(exe, joined, extra, &std::collections::HashMap::new())
}

/// Like [`run_mapped`], but inputs whose names appear in `cached` reuse a
/// pre-converted literal instead of re-converting the host tensor every
/// step. Used for step-invariant inputs (the zero / smoothing teacher
/// distributions) — see EXPERIMENTS.md §Perf.
pub fn run_mapped_cached(
    exe: &Executable,
    joined: &TensorMap,
    extra: &TensorMap,
    cached: &std::collections::HashMap<String, xla::Literal>,
) -> Result<TensorMap> {
    let spec = exe.spec();
    let inputs = joined.assemble(spec, extra)?;
    // Convert only the non-cached inputs.
    let mut fresh: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
    let mut use_cache: Vec<Option<&xla::Literal>> = Vec::with_capacity(inputs.len());
    for (t, ts) in inputs.iter().zip(spec.inputs.iter()) {
        if let Some(l) = cached.get(&ts.name) {
            use_cache.push(Some(l));
        } else {
            use_cache.push(None);
            fresh.push(t.to_literal()?);
        }
    }
    let mut it = fresh.iter();
    let refs: Vec<&xla::Literal> = use_cache
        .iter()
        .map(|slot| slot.unwrap_or_else(|| it.next().expect("fresh literal count")))
        .collect();
    TensorMap::from_outputs(spec, exe.run_refs(&refs)?)
}

impl LmCore {
    #[allow(clippy::too_many_arguments)]
    fn new(
        bundle: &Bundle,
        train_spec: &crate::runtime::Spec,
        seed: u64,
        init_seed: i32,
        streams: &[u64],
        val_streams: &[u64],
        corpus: &CorpusConfig,
        smoothing: SmoothingMode,
        val_batches: usize,
    ) -> Result<Self> {
        let dims = LmDims::from_bundle(bundle)?;
        if corpus.vocab != dims.vocab {
            bail!("corpus vocab {} != bundle vocab {}", corpus.vocab, dims.vocab);
        }
        if val_streams.len() != dims.batch {
            bail!(
                "bundle batch {} != {} validation stream rows",
                dims.batch,
                val_streams.len()
            );
        }
        let init = bundle.exe("init")?;
        let predict = bundle.exe("predict")?;
        let eval_exe = bundle.exe("eval")?;

        let seed_t = Tensor::scalar_i32(init_seed);
        let outs = init.run(&[&seed_t])?;
        let mut vars = TensorMap::from_outputs(init.spec(), outs)?;
        vars.merge(zeros_for_prefix(train_spec, "opt."));
        vars.merge(zeros_for_prefix(train_spec, "state."));

        let tb = dims.unroll * dims.batch;
        let zero_probs = Tensor::full_f32(&[tb, dims.vocab], 0.0);
        let smooth_probs = match &smoothing {
            SmoothingMode::None => None,
            SmoothingMode::Uniform => Some(Tensor::full_f32(
                &[tb, dims.vocab],
                1.0 / dims.vocab as f32,
            )),
            SmoothingMode::Unigram(u) => {
                if u.len() != dims.vocab {
                    bail!("unigram length {} != vocab {}", u.len(), dims.vocab);
                }
                let mut data = Vec::with_capacity(tb * dims.vocab);
                for _ in 0..tb {
                    data.extend_from_slice(u);
                }
                Some(Tensor::f32(&[tb, dims.vocab], data)?)
            }
        };

        let val_state = zeros_for_prefix(eval_exe.spec(), "state.");
        let mut const_lits = std::collections::HashMap::new();
        // The constant ψ target (zeros for plain runs, the smoothing
        // distribution for the Fig 2a baselines) is by far the largest
        // step-invariant input (T·B·V floats); convert it once.
        let const_probs = smooth_probs.as_ref().unwrap_or(&zero_probs);
        const_lits.insert("teacher_probs".to_string(), const_probs.to_literal()?);
        Ok(LmCore {
            dims,
            predict,
            eval_exe,
            vars,
            teachers: Vec::new(),
            smoothing,
            batcher: Batcher::new(corpus, seed, streams, dims.unroll),
            val_batcher: Batcher::new(corpus, seed, val_streams, dims.unroll),
            val_state,
            val_batches,
            zero_probs,
            smooth_probs,
            const_lits,
            snapshot_plane: OnceCell::new(),
            step: 0,
            teacher_fwd: 0,
        })
    }

    /// Teacher soft targets for a batch: mean over teachers' predictions
    /// (Algorithm 1). Advances each teacher's RNN state. The `1/n` mean is
    /// folded into the accumulation itself ([`Tensor::add_scaled`]) so the
    /// ramp path makes one pass per teacher instead of a final rescale.
    fn teacher_probs(&mut self, tokens: &Tensor) -> Result<Tensor> {
        let mut acc: Option<Tensor> = None;
        let n = self.teachers.len();
        let inv = 1.0 / n as f32;
        for t in self.teachers.iter_mut() {
            let mut extra = TensorMap::new();
            extra.insert("tokens", tokens.clone());
            let mut joined = t.params.clone();
            joined.merge(t.state.clone());
            let outs = run_mapped(&self.predict, &joined, &extra)?;
            self.teacher_fwd += 1;
            // carry teacher state forward on this member's streams
            t.state.adopt_prefix(&outs, "state.", "state.");
            let probs = outs.get("probs")?;
            match &mut acc {
                None => {
                    let mut p = probs.clone();
                    if n > 1 {
                        p.scale(inv)?;
                    }
                    acc = Some(p);
                }
                Some(a) => a.add_scaled(probs, inv)?,
            }
        }
        acc.context("teacher_probs with no teachers")
    }

    /// ψ target + effective weight for this step.
    fn distill_inputs(&mut self, tokens: &Tensor, distill_w: f32) -> Result<(Tensor, f32)> {
        if distill_w <= 0.0 {
            return Ok((self.zero_probs.clone(), 0.0));
        }
        match &self.smoothing {
            SmoothingMode::Uniform | SmoothingMode::Unigram(_) => {
                Ok((self.smooth_probs.clone().unwrap(), distill_w))
            }
            SmoothingMode::None => {
                if self.teachers.is_empty() {
                    Ok((self.zero_probs.clone(), 0.0))
                } else {
                    Ok((self.teacher_probs(tokens)?, distill_w))
                }
            }
        }
    }

    fn evaluate(&mut self) -> Result<EvalStats> {
        let mut sum = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..self.val_batches {
            let tokens = self.val_batcher.next_batch()?;
            let mut extra = TensorMap::new();
            extra.insert("tokens", tokens);
            let mut joined = TensorMap::new();
            joined.adopt_prefix(&self.vars, "params.", "params.");
            joined.merge(self.val_state.clone());
            let outs = run_mapped(&self.eval_exe, &joined, &extra)?;
            sum += outs.get("sum_loss")?.item_f32()? as f64;
            count += outs.get("count")?.item_f32()? as f64;
            self.val_state.adopt_prefix(&outs, "state.", "state.");
        }
        Ok(EvalStats {
            loss: sum / count.max(1.0),
            accuracy: None,
        })
    }

    fn snapshot(&self) -> Result<Checkpoint> {
        // Publish straight from `vars` onto the flat plane: the layout is
        // derived once, then every snapshot is a single contiguous gather —
        // no intermediate named map, no per-tensor clones.
        let plane = self
            .snapshot_plane
            .get_or_init(|| Arc::new(FlatLayout::from_map(&self.vars, "params.")))
            .clone();
        Checkpoint::gather_from(0, self.step, plane, &self.vars, "params.")
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> Result<()> {
        // Keep existing per-teacher RNN state when the peer set is stable:
        // stale weights, fresh state (see module docs). When the incoming
        // checkpoint speaks the same flat plane as the installed teacher,
        // the new weights scatter into the existing tensor storage.
        let old = std::mem::take(&mut self.teachers);
        let mut old_iter = old.into_iter();
        let mut new_teachers = Vec::with_capacity(peers.len());
        for ck in peers {
            let incoming = ck.flat().layout();
            let slot = match old_iter.next() {
                Some(mut prev)
                    if Arc::ptr_eq(&prev.plane, incoming)
                        || prev.plane.same_plane(incoming) =>
                {
                    ck.scatter_params_into(&mut prev.params)?;
                    prev.ckpt_step = ck.step;
                    prev.plane = incoming.clone();
                    prev
                }
                _ => Teacher {
                    params: ck.params(),
                    state: zeros_for_prefix(self.predict.spec(), "state."),
                    ckpt_step: ck.step,
                    plane: incoming.clone(),
                },
            };
            new_teachers.push(slot);
        }
        self.teachers = new_teachers;
        Ok(())
    }

    /// Probabilities on an arbitrary token batch using CURRENT params
    /// (zeroed state; diagnostics + §3.4.1 fixed-ensemble teachers).
    fn predict_probs(&self, tokens: &Tensor) -> Result<Tensor> {
        let mut extra = TensorMap::new();
        extra.insert("tokens", tokens.clone());
        let mut joined = TensorMap::new();
        joined.adopt_prefix(&self.vars, "params.", "params.");
        joined.merge(zeros_for_prefix(self.predict.spec(), "state."));
        let outs = run_mapped(&self.predict, &joined, &extra)?;
        Ok(outs.get("probs")?.clone())
    }
}

// ------------------------------------------------------------- fused member

/// One codistilling member simulated as a fused large-batch group.
pub struct LmMember {
    core: LmCore,
    train_step: Arc<Executable>,
}

impl LmMember {
    /// `streams`/`val_streams` come from a [`crate::data::ShardPlan`];
    /// both must have exactly `bundle.batch` rows.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        bundle: &Bundle,
        seed: u64,
        init_seed: i32,
        streams: &[u64],
        val_streams: &[u64],
        corpus: &CorpusConfig,
        smoothing: SmoothingMode,
        val_batches: usize,
    ) -> Result<Self> {
        let train_step = bundle.exe("train_step")?;
        let dims = LmDims::from_bundle(bundle)?;
        if streams.len() != dims.batch {
            bail!("bundle batch {} != {} stream rows", dims.batch, streams.len());
        }
        let core = LmCore::new(
            bundle,
            train_step.spec(),
            seed,
            init_seed,
            streams,
            val_streams,
            corpus,
            smoothing,
            val_batches,
        )?;
        Ok(LmMember { core, train_step })
    }

    pub fn dims(&self) -> LmDims {
        self.core.dims
    }

    pub fn predict_probs(&self, tokens: &Tensor) -> Result<Tensor> {
        self.core.predict_probs(tokens)
    }

    pub fn teacher_forward_count(&self) -> u64 {
        self.core.teacher_fwd
    }

    /// Install a fixed (never-reloaded) teacher set — the offline
    /// distillation phase of §3.4.1.
    pub fn set_fixed_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> Result<()> {
        self.core.set_teachers(peers)
    }

    /// Observed staleness of the current teacher set, in steps.
    pub fn teacher_staleness(&self) -> Vec<u64> {
        self.core
            .teachers
            .iter()
            .map(|t| self.core.step.saturating_sub(t.ckpt_step))
            .collect()
    }
}

impl Member for LmMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> Result<StepStats> {
        let tokens = self.core.batcher.next_batch()?;
        let (probs, w) = self.core.distill_inputs(&tokens, distill_w)?;
        // Constant ψ targets (zeros / smoothing) reuse their pre-converted
        // literal; live teacher predictions convert fresh each step.
        let is_const = match &self.core.smoothing {
            SmoothingMode::None => w == 0.0,
            _ => true,
        };
        let mut extra = TensorMap::new();
        extra.insert("tokens", tokens);
        extra.insert("teacher_probs", probs);
        extra.insert("distill_w", Tensor::scalar_f32(w));
        extra.insert("lr", Tensor::scalar_f32(lr));
        let empty = std::collections::HashMap::new();
        let cache = if is_const { &self.core.const_lits } else { &empty };
        let outs = run_mapped_cached(&self.train_step, &self.core.vars, &extra, cache)?;
        let loss = outs.get("loss")?.item_f32()?;
        let dloss = outs.get("distill_loss")?.item_f32()?;
        self.core.vars.adopt_prefix(&outs, "params.", "params.");
        self.core.vars.adopt_prefix(&outs, "opt.", "opt.");
        self.core.vars.adopt_prefix(&outs, "state.", "state.");
        self.core.step += 1;
        Ok(StepStats {
            step: self.core.step,
            loss,
            distill_loss: dloss,
        })
    }

    fn snapshot(&self) -> Result<Checkpoint> {
        self.core.snapshot()
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> Result<()> {
        self.core.set_teachers(peers)
    }

    fn bootstrap(&mut self, ck: &Checkpoint) -> Result<()> {
        // Mid-run join: adopt the peer's `params.*` plane in place;
        // optimizer/state leaves stay this member's own.
        ck.scatter_params_into(&mut self.core.vars)
    }

    fn evaluate(&mut self) -> Result<EvalStats> {
        self.core.evaluate()
    }

    fn steps_done(&self) -> u64 {
        self.core.step
    }

    fn params(&self) -> &TensorMap {
        &self.core.vars
    }
}

// ------------------------------------------------------- allreduce group

/// The explicit data-parallel sync-SGD group: W workers × per-worker
/// `grad` at batch b, reduced in Rust, applied with `apply`.
pub struct LmSyncGroup {
    core: LmCore,
    grad: Arc<Executable>,
    apply: Arc<Executable>,
    workers: usize,
    worker_batch: usize,
    /// Per-worker batchers (each over its own stream rows) + RNN state.
    worker_data: Vec<Mutex<(Batcher, TensorMap)>>,
    strategy: ReduceStrategy,
    /// Cached `grads.` plane: derived from the first step's worker-0 grads,
    /// reused every step so the flat reduce never re-hashes names.
    grad_plane: OnceCell<Arc<FlatLayout>>,
}

impl LmSyncGroup {
    /// `worker_bundle` must expose `grad`/`apply` at per-worker batch b;
    /// `eval_bundle` (can be the same) provides init/predict/eval.
    /// `streams.len()` must equal `workers * b`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        worker_bundle: &Bundle,
        eval_bundle: &Bundle,
        seed: u64,
        init_seed: i32,
        workers: usize,
        streams: &[u64],
        val_streams: &[u64],
        corpus: &CorpusConfig,
        val_batches: usize,
    ) -> Result<Self> {
        let grad = worker_bundle.exe("grad")?;
        let apply = worker_bundle.exe("apply")?;
        let wdims = LmDims::from_bundle(worker_bundle)?;
        if workers == 0 {
            bail!("LmSyncGroup needs at least one worker");
        }
        if streams.len() != workers * wdims.batch {
            bail!(
                "{} streams for {} workers x batch {}",
                streams.len(),
                workers,
                wdims.batch
            );
        }
        let core = LmCore::new(
            eval_bundle,
            apply.spec(),
            seed,
            init_seed,
            streams, // unused by workers; core batcher unused in group mode
            val_streams,
            corpus,
            SmoothingMode::None,
            val_batches,
        )
        .or_else(|_| {
            // core batcher wants exactly eval-bundle batch rows; reuse the
            // validation rows for the (unused) training batcher.
            LmCore::new(
                eval_bundle,
                apply.spec(),
                seed,
                init_seed,
                val_streams,
                val_streams,
                corpus,
                SmoothingMode::None,
                val_batches,
            )
        })?;
        let mut worker_data = Vec::with_capacity(workers);
        for w in 0..workers {
            let rows = &streams[w * wdims.batch..(w + 1) * wdims.batch];
            let batcher = Batcher::new(corpus, seed, rows, wdims.unroll);
            let state = zeros_for_prefix(grad.spec(), "state.");
            worker_data.push(Mutex::new((batcher, state)));
        }
        Ok(LmSyncGroup {
            core,
            grad,
            apply,
            workers,
            worker_batch: wdims.batch,
            strategy: ReduceStrategy::default(),
            grad_plane: OnceCell::new(),
            worker_data,
        })
    }

    pub fn with_strategy(mut self, s: ReduceStrategy) -> Self {
        self.strategy = s;
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn effective_batch(&self) -> usize {
        self.workers * self.worker_batch
    }

    fn worker_grad(&self, w: usize) -> Result<TensorMap> {
        let mut guard = self.worker_data[w].lock().unwrap();
        let (batcher, state) = &mut *guard;
        let tokens = batcher.next_batch()?;
        let tb = batcher.unroll() * batcher.batch_size();
        let zero_probs = Tensor::full_f32(&[tb, self.core.dims.vocab], 0.0);
        let mut extra = TensorMap::new();
        extra.insert("tokens", tokens);
        extra.insert("teacher_probs", zero_probs);
        extra.insert("distill_w", Tensor::scalar_f32(0.0));
        let mut joined = TensorMap::new();
        joined.adopt_prefix(&self.core.vars, "params.", "params.");
        joined.merge(state.clone());
        let outs = run_mapped(&self.grad, &joined, &extra)?;
        state.adopt_prefix(&outs, "state.", "state.");
        Ok(outs)
    }
}

impl Member for LmSyncGroup {
    fn train_step(&mut self, _distill_w: f32, lr: f32) -> Result<StepStats> {
        // Codistillation at per-worker granularity is exercised through the
        // fused member; the explicit group is the plain-SGD algorithmic
        // path (grad fan-out → allreduce → apply).
        //
        // Worker grads run sequentially on this thread: PJRT wrapper types
        // are not Send (Rc internals), and XLA's CPU client already
        // parallelizes each execution internally. The *reduction* (pure
        // Rust) is thread-parallel: chunk-parallel over the fused plane
        // under the default ReduceStrategy::Flat, pairwise under Tree.
        let per_worker: Vec<TensorMap> = (0..self.workers)
            .map(|w| self.worker_grad(w))
            .collect::<Result<_>>()?;
        let mut loss = 0.0f32;
        for o in &per_worker {
            loss += o.get("loss")?.item_f32()?;
        }
        loss /= self.workers as f32;
        let reduced = match self.strategy {
            // Hot path: reuse the cached grads plane so the steady-state
            // step does no name hashing or layout allocation.
            ReduceStrategy::Flat => {
                let layout = self
                    .grad_plane
                    .get_or_init(|| Arc::new(FlatLayout::from_map(&per_worker[0], "grads.")))
                    .clone();
                allreduce_mean_flat(per_worker, layout)?
            }
            s => allreduce_mean(per_worker, "grads.", s)?,
        };

        let mut extra = TensorMap::new();
        extra.insert("lr", Tensor::scalar_f32(lr));
        let mut joined = TensorMap::new();
        joined.adopt_prefix(&self.core.vars, "params.", "params.");
        joined.adopt_prefix(&self.core.vars, "opt.", "opt.");
        joined.adopt_prefix(&reduced, "grads.", "grads.");
        let outs = run_mapped(&self.apply, &joined, &extra)?;
        self.core.vars.adopt_prefix(&outs, "params.", "params.");
        self.core.vars.adopt_prefix(&outs, "opt.", "opt.");
        self.core.step += 1;
        Ok(StepStats {
            step: self.core.step,
            loss,
            distill_loss: 0.0,
        })
    }

    fn snapshot(&self) -> Result<Checkpoint> {
        self.core.snapshot()
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> Result<()> {
        self.core.set_teachers(peers)
    }

    fn bootstrap(&mut self, ck: &Checkpoint) -> Result<()> {
        // A whole joining group seeds its shared params from the peer
        // snapshot; per-worker optimizer state stays local.
        ck.scatter_params_into(&mut self.core.vars)
    }

    fn evaluate(&mut self) -> Result<EvalStats> {
        self.core.evaluate()
    }

    fn steps_done(&self) -> u64 {
        self.core.step
    }

    fn params(&self) -> &TensorMap {
        &self.core.vars
    }
}

//! The image-classification member (Fig 3 / ImageNet stand-in).
//!
//! Momentum SGD with the Goyal-style warmup schedule is supplied by the
//! orchestrator's [`LrSchedule`](crate::codistill::LrSchedule); accuracy is
//! the Fig 3 y-axis so `evaluate` reports top-1 as well as loss.

use crate::codistill::{Checkpoint, EvalStats, Member, StepStats};
use crate::data::images::{ImageBatch, ImageGen};
use crate::models::lm::{run_mapped, zeros_for_prefix};
use crate::runtime::{Bundle, Executable, Tensor, TensorMap};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Fixed validation set shared by all members of an experiment.
pub struct ImagesValSet {
    pub batches: Vec<ImageBatch>,
}

impl ImagesValSet {
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        seed: u64,
        stream: u64,
        size: usize,
        channels: usize,
        classes: usize,
        batch: usize,
        n: usize,
        noise: f64,
    ) -> Result<Arc<Self>> {
        let mut gen = ImageGen::new(seed, stream, size, channels, classes).with_noise(noise);
        let batches = (0..n)
            .map(|_| gen.next_batch(batch))
            .collect::<Result<Vec<_>>>()?;
        Ok(Arc::new(ImagesValSet { batches }))
    }
}

pub struct ImagesMember {
    train_step: Arc<Executable>,
    predict: Arc<Executable>,
    eval_exe: Arc<Executable>,
    vars: TensorMap,
    teachers: Vec<TensorMap>,
    gen: ImageGen,
    val: Arc<ImagesValSet>,
    batch: usize,
    classes: usize,
    step: u64,
}

impl ImagesMember {
    pub fn new(
        bundle: &Bundle,
        data_seed: u64,
        stream: u64,
        init_seed: i32,
        noise: f64,
        val: Arc<ImagesValSet>,
    ) -> Result<Self> {
        let train_step = bundle.exe("train_step")?;
        let predict = bundle.exe("predict")?;
        let eval_exe = bundle.exe("eval")?;
        let batch = bundle.meta_usize("batch")?;
        let size = bundle.meta_usize("size")?;
        let channels = bundle.meta_usize("channels")?;
        let classes = bundle.meta_usize("classes")?;
        let init = bundle.exe("init")?;
        let outs = init.run(&[&Tensor::scalar_i32(init_seed)])?;
        let mut vars = TensorMap::from_outputs(init.spec(), outs)?;
        vars.merge(zeros_for_prefix(train_step.spec(), "opt."));
        Ok(ImagesMember {
            train_step,
            predict,
            eval_exe,
            vars,
            teachers: Vec::new(),
            gen: ImageGen::new(data_seed, stream, size, channels, classes).with_noise(noise),
            val,
            batch,
            classes,
            step: 0,
        })
    }

    fn teacher_probs(&mut self, batch: &ImageBatch) -> Result<Tensor> {
        let mut acc: Option<Tensor> = None;
        for t in &self.teachers {
            let mut extra = TensorMap::new();
            extra.insert("images", batch.images.clone());
            let outs = run_mapped(&self.predict, t, &extra)?;
            let p = outs.get("probs")?.clone();
            match &mut acc {
                None => acc = Some(p),
                Some(a) => a.add_assign(&p)?,
            }
        }
        let mut p = acc.context("no teachers")?;
        if self.teachers.len() > 1 {
            p.scale(1.0 / self.teachers.len() as f32)?;
        }
        Ok(p)
    }
}

impl Member for ImagesMember {
    fn train_step(&mut self, distill_w: f32, lr: f32) -> Result<StepStats> {
        let batch = self.gen.next_batch(self.batch)?;
        let (probs, w) = if distill_w > 0.0 && !self.teachers.is_empty() {
            (self.teacher_probs(&batch)?, distill_w)
        } else {
            (Tensor::full_f32(&[self.batch, self.classes], 0.0), 0.0)
        };
        let mut extra = TensorMap::new();
        extra.insert("images", batch.images);
        extra.insert("labels", batch.labels);
        extra.insert("teacher_probs", probs);
        extra.insert("distill_w", Tensor::scalar_f32(w));
        extra.insert("lr", Tensor::scalar_f32(lr));
        let outs = run_mapped(&self.train_step, &self.vars, &extra)?;
        let loss = outs.get("loss")?.item_f32()?;
        let dloss = outs.get("distill_loss")?.item_f32()?;
        self.vars.adopt_prefix(&outs, "params.", "params.");
        self.vars.adopt_prefix(&outs, "opt.", "opt.");
        self.step += 1;
        Ok(StepStats {
            step: self.step,
            loss,
            distill_loss: dloss,
        })
    }

    fn snapshot(&self) -> Result<Checkpoint> {
        let mut params = TensorMap::new();
        params.adopt_prefix(&self.vars, "params.", "params.");
        Ok(Checkpoint::new(0, self.step, params))
    }

    fn set_teachers(&mut self, peers: Vec<Arc<Checkpoint>>) -> Result<()> {
        // Refresh each teacher in place when the peer's plane lines up
        // with the installed storage; rebuild otherwise.
        let mut old = std::mem::take(&mut self.teachers).into_iter();
        self.teachers = peers
            .into_iter()
            .map(|c| match old.next() {
                Some(prev) => c.refresh_params(prev),
                None => Ok(c.params()),
            })
            .collect::<Result<_>>()?;
        Ok(())
    }

    fn evaluate(&mut self) -> Result<EvalStats> {
        let mut sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut count = 0.0f64;
        for b in &self.val.batches {
            let mut extra = TensorMap::new();
            extra.insert("images", b.images.clone());
            extra.insert("labels", b.labels.clone());
            let outs = run_mapped(&self.eval_exe, &self.vars, &extra)?;
            sum += outs.get("sum_loss")?.item_f32()? as f64;
            correct += outs.get("correct")?.item_f32()? as f64;
            count += outs.get("count")?.item_f32()? as f64;
        }
        Ok(EvalStats {
            loss: sum / count.max(1.0),
            accuracy: Some(correct / count.max(1.0)),
        })
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn params(&self) -> &TensorMap {
        &self.vars
    }
}

//! cargo-bench wrapper for the `table1` experiment (harness=false).
//!
//! Runs a scaled-down-but-representative configuration by default so the
//! whole bench suite completes in minutes; pass key=value args after
//! `cargo bench --bench table1_churn -- ` to override (e.g. steps=600 for the
//! full EXPERIMENTS.md configuration).

use codistill::config::Settings;

fn main() {
    let mut s = Settings::new();
    for kv in ["repeats=1", "steps=150", "burn_in=40", "reload=15", ] {
        s.apply(kv).unwrap();
    }
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv).unwrap();
    }
    let t0 = std::time::Instant::now();
    codistill::experiments::table1::run(&s).expect("table1 failed");
    println!("[bench:table1_churn] completed in {:.1}s", t0.elapsed().as_secs_f64());
}

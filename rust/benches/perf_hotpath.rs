//! Hot-path microbenchmarks (harness=false): the numbers behind the README
//! §Perf table, emitted both as a human table and as machine-readable
//! `BENCH_hotpath.json` so the perf trajectory is tracked PR over PR.
//!
//! Measures, per layer-3 hot spot:
//!   * fused `train_step` latency (the floor set by L1/L2);
//!   * teacher `predict` latency (codistillation's extra forward pass —
//!     the paper argues this is nearly free; here we print the ratio);
//!   * allreduce strategies (naive vs tree vs flat) at LM-gradient sizes;
//!   * the flat plane itself: gather/scatter and checkpoint save/load/
//!     publish on a ~1M-element parameter set;
//!   * incremental (delta) exchange: full vs delta fetch bytes and time
//!     at changed fractions {1.0, 0.25, 0.05} over each transport, plus
//!     flat-vs-tree allreduce across worker counts {2, 4, 8, 16};
//!   * compressed exchange: full vs delta vs delta+codec payload bytes
//!     (CKPT0004 spool files / encoded socket DELTA frames) at the same
//!     changed fractions — the `sections.compressed_exchange` rows;
//!   * checkpoint fan-out: {8, 64, 512} concurrent readers pulling one
//!     small plane, direct from the hub vs through a two-relay tier
//!     (`codistill::transport::Relay`) — the `sections.fanout` rows
//!     behind the README fan-out recipe;
//!   * the serving tier (`codistill::serve`): flat-out open-loop goodput
//!     at several micro-batch caps over the mock forward, plus the cost
//!     of a verified hot swap landing on a live server — the
//!     `sections.serving` rows;
//!   * tensor<->literal boundary cost (runtime overhead);
//!   * explicit sync-SGD group step vs fused equivalent (coordinator
//!     overhead).
//!
//! Sections that need compiled artifacts (or a real PJRT backend) are
//! skipped gracefully and recorded as `null` in the JSON, so the pure-Rust
//! coordinator numbers are tracked even on machines without XLA.

use codistill::codistill::serve::{open_loop, InferenceServer, LoadSpec, OpenLoopSpec, ServeConfig};
use codistill::codistill::transport::{Basis, Codec, ErrorFeedback, FetchSpec, ANY_STEP};
use codistill::codistill::{
    Checkpoint, ExchangeTransport, InProcess, Member, Relay, RelayConfig, SocketServer,
    SocketTransport, SpoolDir,
};
use codistill::config::Settings;
use codistill::models::MockForward;
use codistill::testkit::DriftMember;
use codistill::data::corpus::Batcher;
use codistill::data::shard::{ShardMode, ShardPlan};
use codistill::experiments::common::{corpus_for, lm_member, open_bundle};
use codistill::models::lm::{LmSyncGroup, SmoothingMode};
use codistill::runtime::flat::{FlatBuffer, FlatLayout};
use codistill::runtime::{Tensor, TensorMap};
use codistill::sgd::allreduce::{allreduce_mean, ReduceStrategy};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn ms(v: Option<f64>) -> String {
    match v {
        Some(s) => format!("{:.4}", s * 1e3),
        None => "null".to_string(),
    }
}

/// Artifact-backed section results (None = skipped: no artifacts/backend).
#[derive(Default)]
struct ArtifactTimes {
    train_step: Option<f64>,
    teacher_predict: Option<f64>,
    codistill_step: Option<f64>,
    sync_group_step: Option<f64>,
}

fn run_artifact_benches(s: &Settings, iters: usize, out: &mut ArtifactTimes) -> anyhow::Result<()> {
    // ---- train_step + predict latency (fused member).
    let bundle = open_bundle(s, "lm_b64")?;
    let plan = ShardPlan::new(1, 64, ShardMode::Disjoint);
    let mut member = lm_member(&bundle, &plan, 0, 7, 1, SmoothingMode::None, 2)?;
    member.train_step(0.0, 0.03)?; // warmup/compile
    let t_step = time_n(iters, || {
        member.train_step(0.0, 0.03).unwrap();
    });
    out.train_step = Some(t_step);
    println!("train_step(b=64):        {:>8.2} ms", t_step * 1e3);

    let corpus = corpus_for(&bundle)?;
    let streams: Vec<u64> = (500..564).collect();
    let mut batcher = Batcher::new(&corpus, 7, &streams, 16);
    let tokens = batcher.next_batch()?;
    member.predict_probs(&tokens)?;
    let t_pred = time_n(iters, || {
        member.predict_probs(&tokens).unwrap();
    });
    out.teacher_predict = Some(t_pred);
    println!(
        "teacher predict(b=64):   {:>8.2} ms  ({:.0}% of a train step; paper: \"worst case ~50%\")",
        t_pred * 1e3,
        100.0 * t_pred / t_step
    );

    // ---- codistillation step (train + teacher forward).
    let mut a = lm_member(&bundle, &plan, 0, 9, 1, SmoothingMode::None, 2)?;
    let b = lm_member(&bundle, &plan, 0, 9, 2, SmoothingMode::None, 2)?;
    a.set_fixed_teachers(vec![Arc::new(b.snapshot()?)])?;
    a.train_step(1.0, 0.03)?;
    let t_codist = time_n(iters, || {
        a.train_step(1.0, 0.03).unwrap();
    });
    out.codistill_step = Some(t_codist);
    println!(
        "codistill step(b=64):    {:>8.2} ms  ({:.2}x baseline step)",
        t_codist * 1e3,
        t_codist / t_step
    );

    // ---- explicit allreduce group step vs fused equivalent.
    let worker_bundle = open_bundle(s, "lm_w8")?;
    let group_streams: Vec<u64> = (0..64).collect();
    let val_streams: Vec<u64> = (2_000_000..2_000_064).collect();
    let mut group = LmSyncGroup::new(
        &worker_bundle,
        &bundle,
        7,
        1,
        8,
        &group_streams,
        &val_streams,
        &corpus,
        2,
    )?
    // `reduce=naive|tree|flat` picks the group's reduction strategy.
    .with_strategy(ReduceStrategy::parse(s.str_or("reduce", "flat"))?);
    group.train_step(0.0, 0.03)?;
    let t_group = time_n(iters.min(5), || {
        group.train_step(0.0, 0.03).unwrap();
    });
    out.sync_group_step = Some(t_group);
    println!(
        "sync group step (8x b=8):{:>8.2} ms  (coordinator overhead vs fused: {:.2}x)",
        t_group * 1e3,
        t_group / t_step
    );
    Ok(())
}

/// A ragged ~`total`-element parameter map (LM-like leaf size spread):
/// six big windows covering 63/64 of the budget, then a tail of ~1k-element
/// vectors, so per-window overhead is actually represented.
fn ragged_params(total: usize) -> TensorMap {
    let mut m = TensorMap::new();
    let mut left = total;
    let mut i = 0usize;
    for frac in [2usize, 4, 8, 16, 32, 64] {
        let n = (total / frac).max(1).min(left);
        if n == 0 {
            break;
        }
        m.insert(
            format!("params.w{i:02}"),
            Tensor::f32(&[n], vec![0.1 * i as f32; n]).unwrap(),
        );
        left -= n;
        i += 1;
    }
    while left > 0 {
        let n = left.min(1000);
        m.insert(
            format!("params.w{i:02}"),
            Tensor::f32(&[n], vec![0.1 * i as f32; n]).unwrap(),
        );
        left -= n;
        i += 1;
    }
    m
}

fn main() {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv).unwrap();
    }
    let iters = s.usize_or("iters", 10).unwrap();
    let json_path = s.str_or("json", "BENCH_hotpath.json").to_string();

    // ---- artifact-backed sections (skip cleanly without artifacts/XLA).
    let mut art = ArtifactTimes::default();
    if let Err(e) = run_artifact_benches(&s, iters, &mut art) {
        eprintln!("skipping artifact-backed sections: {e:#}");
    }

    // ---- allreduce strategies at paper-ish gradient sizes.
    let mut allreduce_rows: Vec<String> = Vec::new();
    for (workers, numel) in [(8usize, 65_536usize), (32, 65_536), (8, 1_048_576)] {
        let make = || -> Vec<TensorMap> {
            (0..workers)
                .map(|w| {
                    let mut m = TensorMap::new();
                    m.insert(
                        "grads.w",
                        Tensor::f32(&[numel], vec![w as f32; numel]).unwrap(),
                    );
                    m
                })
                .collect()
        };
        let t_naive = time_n(5, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Naive).unwrap();
        });
        let t_tree = time_n(5, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Tree).unwrap();
        });
        let t_flat = time_n(5, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Flat).unwrap();
        });
        println!(
            "allreduce w={workers:<2} n={numel:>8}: naive {:>7.2} ms, tree {:>7.2} ms, flat {:>7.2} ms (flat {:.2}x vs tree)",
            t_naive * 1e3,
            t_tree * 1e3,
            t_flat * 1e3,
            t_tree / t_flat
        );
        allreduce_rows.push(format!(
            "{{\"workers\": {workers}, \"numel\": {numel}, \"naive_ms\": {}, \"tree_ms\": {}, \"flat_ms\": {}}}",
            ms(Some(t_naive)),
            ms(Some(t_tree)),
            ms(Some(t_flat))
        ));
    }

    // ---- ROADMAP trajectory: flat vs tree across worker counts at one
    // LM-ish gradient size (the plotted scaling curve).
    let mut allreduce_scaling_rows: Vec<String> = Vec::new();
    for workers in [2usize, 4, 8, 16] {
        let numel = 262_144usize;
        let make = || -> Vec<TensorMap> {
            (0..workers)
                .map(|w| {
                    let mut m = TensorMap::new();
                    m.insert(
                        "grads.w",
                        Tensor::f32(&[numel], vec![w as f32; numel]).unwrap(),
                    );
                    m
                })
                .collect()
        };
        let t_tree = time_n(3, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Tree).unwrap();
        });
        let t_flat = time_n(3, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Flat).unwrap();
        });
        println!(
            "allreduce scaling w={workers:<2} n={numel}: tree {:>7.2} ms, flat {:>7.2} ms ({:.2}x)",
            t_tree * 1e3,
            t_flat * 1e3,
            t_tree / t_flat
        );
        allreduce_scaling_rows.push(format!(
            "{{\"workers\": {workers}, \"numel\": {numel}, \"tree_ms\": {}, \"flat_ms\": {}}}",
            ms(Some(t_tree)),
            ms(Some(t_flat))
        ));
    }

    // ---- the flat plane itself: gather/scatter + checkpoint exchange.
    let params = ragged_params(1_048_576);
    let layout = Arc::new(FlatLayout::from_map(&params, "params."));
    let t_gather = time_n(20, || {
        FlatBuffer::gather(layout.clone(), &params).unwrap();
    });
    let buf = FlatBuffer::gather(layout.clone(), &params).unwrap();
    let mut dst = ragged_params(1_048_576);
    let t_scatter = time_n(20, || {
        buf.scatter_into(&mut dst).unwrap();
    });
    println!(
        "flat gather/scatter(4MB):{:>8.2} ms / {:.2} ms ({} windows)",
        t_gather * 1e3,
        t_scatter * 1e3,
        layout.len()
    );

    let store = InProcess::new(4);
    // Share one plane across iterations: the real publish path hands the
    // store an Arc to the member's already-gathered buffer, so the timed
    // loop must not include a fresh 4 MB copy.
    let plane = Arc::new(buf.clone());
    let t_publish = time_n(20, || {
        let ck = Checkpoint::from_flat(0, 1, plane.clone(), TensorMap::new());
        store.publish(ck).unwrap();
        store.latest(0).unwrap();
    });
    println!("ckpt publish+latest:     {:>8.2} ms  (zero-copy plane hand-off)", t_publish * 1e3);

    let dir = std::env::temp_dir().join(format!("codistill_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.ckpt");
    let ck = Checkpoint::from_flat(0, 1, plane.clone(), TensorMap::new());
    let t_save = time_n(5, || {
        ck.save(&path).unwrap();
    });
    let t_load = time_n(5, || {
        Checkpoint::load(&path).unwrap();
    });
    println!(
        "ckpt save/load (4MB):    {:>8.2} ms / {:.2} ms  (contiguous CKPT0002 payload)",
        t_save * 1e3,
        t_load * 1e3
    );
    std::fs::remove_dir_all(&dir).ok();

    // ---- the same ~4MB plane through each exchange transport: publish,
    // full-plane fetch (latest), and windowed fetch (all windows by name;
    // for `socket-windowed`, even `latest` reassembles from batched
    // window requests instead of one full-plane response).
    let window_names: Vec<String> = layout.names().map(|s| s.to_string()).collect();
    let mut transport_rows: Vec<String> = Vec::new();
    {
        let spool_dir =
            std::env::temp_dir().join(format!("codistill_bench_spool_{}", std::process::id()));
        std::fs::remove_dir_all(&spool_dir).ok();
        let server =
            SocketServer::bind_tcp("127.0.0.1:0", 4).expect("binding bench exchange server");
        let inproc: Arc<dyn ExchangeTransport> = Arc::new(InProcess::new(4));
        let socket: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(server.addr()));
        let socket_windowed: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(server.addr()).with_windowed_fetch(8));
        // Publisher and reader are separate handles where the medium
        // allows it: a second SpoolDir on the same directory models a
        // reading process, so fetches pay real file reads instead of
        // hitting the publisher's in-memory cache (full-plane spool reads
        // additionally use a fresh handle per iteration — the reader
        // handle itself caches repeat loads of one step).
        let backends: Vec<(&str, Arc<dyn ExchangeTransport>, Arc<dyn ExchangeTransport>)> = vec![
            ("inproc", inproc.clone(), inproc),
            (
                "spool",
                Arc::new(SpoolDir::open(&spool_dir, 4).expect("opening bench spool")),
                Arc::new(SpoolDir::open(&spool_dir, 4).expect("opening bench spool")),
            ),
            ("socket", socket.clone(), socket),
            (
                "socket-windowed",
                socket_windowed.clone(),
                socket_windowed,
            ),
        ];
        for (member, (name, publisher, reader)) in backends.iter().enumerate() {
            let mut step = 0u64;
            let t_publish = time_n(5, || {
                step += 1;
                publisher
                    .publish(Checkpoint::from_flat(
                        member,
                        step,
                        plane.clone(),
                        TensorMap::new(),
                    ))
                    .unwrap();
            });
            let t_full = if *name == "spool" {
                time_n(5, || {
                    SpoolDir::open(&spool_dir, 4)
                        .unwrap()
                        .latest(member)
                        .unwrap()
                        .unwrap();
                })
            } else {
                time_n(5, || {
                    reader.latest(member).unwrap().unwrap();
                })
            };
            let t_windowed = time_n(5, || {
                reader
                    .fetch_windows(member, u64::MAX, &window_names)
                    .unwrap()
                    .unwrap();
            });
            println!(
                "exchange {name:>15}: publish {:>7.2} ms, fetch full {:>7.2} ms, windowed {:>7.2} ms",
                t_publish * 1e3,
                t_full * 1e3,
                t_windowed * 1e3
            );
            transport_rows.push(format!(
                "{{\"name\": \"{name}\", \"publish_ms\": {}, \"fetch_full_ms\": {}, \"fetch_windowed_ms\": {}}}",
                ms(Some(t_publish)),
                ms(Some(t_full)),
                ms(Some(t_windowed))
            ));
        }
        drop(backends);
        std::fs::remove_dir_all(&spool_dir).ok();
    }

    // ---- incremental (delta) exchange: full vs delta fetch of the same
    // ~4MB plane when only a fraction of its bytes changed since the
    // reader's installed basis. Changed windows are picked
    // smallest-first until the byte budget is met, so the fraction is
    // honest about bytes, not window counts.
    let mut delta_rows: Vec<String> = Vec::new();
    for frac in [1.0f64, 0.25, 0.05] {
        // v2 plane: `frac` of the v1 bytes rewritten
        let (v2, changed_elems) = {
            let mut b = (*plane).clone();
            let target = (frac * layout.total_len() as f64) as usize;
            let mut entries: Vec<_> = layout.entries().iter().collect();
            entries.sort_by_key(|e| e.len);
            let mut changed = 0usize;
            for e in entries {
                if changed + e.len <= target {
                    for v in &mut b.data_mut()[e.range()] {
                        *v += 1.0;
                    }
                    changed += e.len;
                }
            }
            (Arc::new(b), changed)
        };
        let spool_dir = std::env::temp_dir().join(format!(
            "codistill_bench_delta_{}_{}",
            std::process::id(),
            (frac * 100.0) as u32
        ));
        std::fs::remove_dir_all(&spool_dir).ok();
        let server =
            SocketServer::bind_tcp("127.0.0.1:0", 4).expect("binding delta bench server");
        let backends: Vec<(&str, Arc<dyn ExchangeTransport>)> = vec![
            ("inproc", Arc::new(InProcess::new(4))),
            (
                "spool",
                Arc::new(SpoolDir::open(&spool_dir, 4).expect("opening delta bench spool")),
            ),
            ("socket", Arc::new(SocketTransport::connect_tcp(server.addr()))),
        ];
        for (member, (name, transport)) in backends.iter().enumerate() {
            let ck1 = Checkpoint::from_flat(member, 1, plane.clone(), TensorMap::new());
            let basis = Basis {
                step: 1,
                digests: ck1.window_digests().as_ref().clone(),
            };
            transport.publish(ck1).unwrap();
            transport
                .publish(Checkpoint::from_flat(member, 2, v2.clone(), TensorMap::new()))
                .unwrap();
            let full_spec = FetchSpec::full(member, ANY_STEP);
            let delta_spec = FetchSpec::full(member, ANY_STEP).with_basis(basis);
            // spool reads go through a fresh handle per fetch so the
            // read cache cannot hide the file IO (same policy as the
            // transport section above)
            let fetch = |spec: &FetchSpec| {
                if *name == "spool" {
                    SpoolDir::open(&spool_dir, 4).unwrap().fetch(spec).unwrap().unwrap()
                } else {
                    transport.fetch(spec).unwrap().unwrap()
                }
            };
            let full_bytes = fetch(&full_spec).payload_bytes();
            let delta_bytes = fetch(&delta_spec).payload_bytes();
            let t_full = time_n(3, || {
                fetch(&full_spec);
            });
            let t_delta = time_n(3, || {
                fetch(&delta_spec);
            });
            println!(
                "delta {name:>7} frac={frac:<4}: full {:>7.2} ms / {full_bytes:>8} B, \
                 delta {:>7.2} ms / {delta_bytes:>8} B ({:.1}% of full)",
                t_full * 1e3,
                t_delta * 1e3,
                100.0 * delta_bytes as f64 / full_bytes as f64
            );
            delta_rows.push(format!(
                "{{\"transport\": \"{name}\", \"changed_fraction\": {frac}, \
                 \"changed_elems\": {changed_elems}, \
                 \"fetch_full_ms\": {}, \"fetch_delta_ms\": {}, \
                 \"full_payload_bytes\": {full_bytes}, \"delta_payload_bytes\": {delta_bytes}}}",
                ms(Some(t_full)),
                ms(Some(t_delta))
            ));
        }
        drop(backends);
        drop(server);
        std::fs::remove_dir_all(&spool_dir).ok();
    }

    // ---- compressed exchange: full vs delta vs delta+codec over the
    // media where bytes actually cross a boundary (spool files, socket
    // frames). The codec rows publish through CKPT0004 (spool) or
    // negotiate encoded DELTA frames (socket); the delta rows are the
    // raw-frame baseline on an identically changed plane. The JSON pins
    // the ROADMAP claim that delta+codec moves fewer bytes than delta
    // alone whenever windows compress.
    let mut compressed_rows: Vec<String> = Vec::new();
    for frac in [1.0f64, 0.25, 0.05] {
        let v2 = {
            let mut b = (*plane).clone();
            let target = (frac * layout.total_len() as f64) as usize;
            let mut entries: Vec<_> = layout.entries().iter().collect();
            entries.sort_by_key(|e| e.len);
            let mut changed = 0usize;
            for e in entries {
                if changed + e.len <= target {
                    for v in &mut b.data_mut()[e.range()] {
                        *v += 1.0;
                    }
                    changed += e.len;
                }
            }
            Arc::new(b)
        };
        let tag = (frac * 100.0) as u32;
        let raw_dir = std::env::temp_dir().join(format!(
            "codistill_bench_comp_raw_{}_{tag}",
            std::process::id()
        ));
        let enc_dir = std::env::temp_dir().join(format!(
            "codistill_bench_comp_enc_{}_{tag}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&raw_dir).ok();
        std::fs::remove_dir_all(&enc_dir).ok();
        let server =
            SocketServer::bind_tcp("127.0.0.1:0", 4).expect("binding compress bench server");
        // (name, raw-reading transport, codec-reading transport,
        // publisher for raw medium, publisher for codec medium)
        let spool_raw: Arc<dyn ExchangeTransport> =
            Arc::new(SpoolDir::open(&raw_dir, 4).expect("opening raw spool"));
        let spool_enc: Arc<dyn ExchangeTransport> = Arc::new(
            SpoolDir::open(&enc_dir, 4)
                .expect("opening codec spool")
                .with_codec(Codec::Shuffle),
        );
        let sock_raw: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(server.addr()));
        let sock_enc: Arc<dyn ExchangeTransport> =
            Arc::new(SocketTransport::connect_tcp(server.addr()).with_codec(Codec::Shuffle));
        let cases: Vec<(&str, Arc<dyn ExchangeTransport>, Arc<dyn ExchangeTransport>)> = vec![
            ("spool", spool_raw, spool_enc),
            ("socket", sock_raw, sock_enc),
        ];
        for (member, (name, raw_t, enc_t)) in cases.iter().enumerate() {
            let ck1 = Checkpoint::from_flat(member, 1, plane.clone(), TensorMap::new());
            let basis = Basis {
                step: 1,
                digests: ck1.window_digests().as_ref().clone(),
            };
            // spool raw/codec are distinct directories and need their own
            // publications; the socket pair shares one server store, so
            // publishing through the raw client covers both readers
            let publishers: Vec<&Arc<dyn ExchangeTransport>> = if *name == "spool" {
                vec![raw_t, enc_t]
            } else {
                vec![raw_t]
            };
            for t in publishers {
                t.publish(Checkpoint::from_flat(member, 1, plane.clone(), TensorMap::new()))
                    .unwrap();
                t.publish(Checkpoint::from_flat(member, 2, v2.clone(), TensorMap::new()))
                    .unwrap();
            }
            let full_spec = FetchSpec::full(member, ANY_STEP);
            let delta_spec = FetchSpec::full(member, ANY_STEP).with_basis(basis);
            // fresh spool handles per fetch so the read cache cannot
            // hide the file IO (same policy as the delta section)
            let fetch = |t: &Arc<dyn ExchangeTransport>, dir: &std::path::Path, spec: &FetchSpec| {
                if *name == "spool" {
                    SpoolDir::open(dir, 4).unwrap().fetch(spec).unwrap().unwrap()
                } else {
                    t.fetch(spec).unwrap().unwrap()
                }
            };
            let full_bytes = fetch(raw_t, &raw_dir, &full_spec).payload_bytes();
            let delta_bytes = fetch(raw_t, &raw_dir, &delta_spec).payload_bytes();
            let codec_bytes = fetch(enc_t, &enc_dir, &delta_spec).payload_bytes();
            let t_full = time_n(3, || {
                fetch(raw_t, &raw_dir, &full_spec);
            });
            let t_delta = time_n(3, || {
                fetch(raw_t, &raw_dir, &delta_spec);
            });
            let t_codec = time_n(3, || {
                fetch(enc_t, &enc_dir, &delta_spec);
            });
            println!(
                "compress {name:>7} frac={frac:<4}: full {full_bytes:>8} B, delta {delta_bytes:>8} B, \
                 delta+codec {codec_bytes:>8} B ({:.1}% of delta; {:.2}/{:.2}/{:.2} ms)",
                100.0 * codec_bytes as f64 / delta_bytes.max(1) as f64,
                t_full * 1e3,
                t_delta * 1e3,
                t_codec * 1e3
            );
            compressed_rows.push(format!(
                "{{\"transport\": \"{name}\", \"changed_fraction\": {frac}, \
                 \"full_payload_bytes\": {full_bytes}, \"delta_payload_bytes\": {delta_bytes}, \
                 \"codec_payload_bytes\": {codec_bytes}, \
                 \"fetch_full_ms\": {}, \"fetch_delta_ms\": {}, \"fetch_codec_ms\": {}}}",
                ms(Some(t_full)),
                ms(Some(t_delta)),
                ms(Some(t_codec))
            ));
        }
        drop(cases);
        drop(server);
        std::fs::remove_dir_all(&raw_dir).ok();
        std::fs::remove_dir_all(&enc_dir).ok();
    }

    // ---- lossy exchange: raw vs RLE vs fp16 vs int8 (± error feedback)
    // deltas on a plane with real mantissa entropy. The constant-valued
    // bench plane above is byte-shuffle+RLE's best case; quantizers earn
    // their keep once window bytes stop repeating, so these rows fill
    // the same layout with 1024 hash-scattered values per window
    // (`0.5 + ((i·2654435761) mod 1024)·1e-3`, window amax ≈ 1.52 so
    // every window sits on one int8 power-of-two scale) and shift the
    // changed windows by +0.125 — exactly 8 steps of the 2⁻⁶ int8 grid,
    // so planes prepared through the publisher-side [`ErrorFeedback`]
    // path stay value-idempotent through CKPT0005 spool files and
    // encoded socket DELTA frames alike. Pins the ISSUE-9 acceptance
    // gate: at changed_fraction 0.25 the int8 delta moves at most half
    // the payload bytes of the delta+RLE baseline.
    {
        let frac = 0.25f64;
        let scatter = |b: &mut FlatBuffer| {
            for (i, v) in b.data_mut().iter_mut().enumerate() {
                *v = 0.5 + ((i as u64).wrapping_mul(2654435761) % 1024) as f32 * 1e-3;
            }
        };
        let ramp = {
            let mut b = (*plane).clone();
            scatter(&mut b);
            Arc::new(b)
        };
        let ramp2 = {
            let mut b = (*ramp).clone();
            let target = (frac * layout.total_len() as f64) as usize;
            let mut entries: Vec<_> = layout.entries().iter().collect();
            entries.sort_by_key(|e| e.len);
            let mut changed = 0usize;
            for e in entries {
                if changed + e.len <= target {
                    for v in &mut b.data_mut()[e.range()] {
                        *v += 0.125;
                    }
                    changed += e.len;
                }
            }
            Arc::new(b)
        };
        let server =
            SocketServer::bind_tcp("127.0.0.1:0", 4).expect("binding lossy bench server");
        let rows: &[(&str, Codec, bool)] = &[
            ("raw", Codec::Raw, false),
            ("rle", Codec::Shuffle, false),
            ("fp16", Codec::Fp16, false),
            ("int8", Codec::Int8, false),
            ("int8+fb", Codec::Int8, true),
        ];
        let mut spool_delta: std::collections::HashMap<&str, usize> = Default::default();
        for (member, (label, codec, feedback)) in rows.iter().enumerate() {
            // publish exactly what the orchestrator would: planes that
            // already went through the quantize-at-publish round trip
            let mut prep = ErrorFeedback::new(*codec, *feedback);
            let ck1 = prep
                .prepare(Checkpoint::from_flat(member, 1, ramp.clone(), TensorMap::new()))
                .unwrap();
            let ck2 = prep
                .prepare(Checkpoint::from_flat(member, 2, ramp2.clone(), TensorMap::new()))
                .unwrap();
            let basis = Basis {
                step: 1,
                digests: ck1.window_digests().as_ref().clone(),
            };
            let dir = std::env::temp_dir().join(format!(
                "codistill_bench_lossy_{}_{member}",
                std::process::id()
            ));
            std::fs::remove_dir_all(&dir).ok();
            let spool = SpoolDir::open(&dir, 4)
                .expect("opening lossy spool")
                .with_codec(*codec);
            let sock_pub = SocketTransport::connect_tcp(server.addr());
            let sock = if *codec == Codec::Raw {
                SocketTransport::connect_tcp(server.addr())
            } else {
                SocketTransport::connect_tcp(server.addr()).with_codec(*codec)
            };
            spool.publish(ck1.clone()).unwrap();
            spool.publish(ck2.clone()).unwrap();
            sock_pub.publish(ck1).unwrap();
            sock_pub.publish(ck2).unwrap();
            let delta_spec = FetchSpec::full(member, ANY_STEP).with_basis(basis);
            let spool_fetch =
                || SpoolDir::open(&dir, 4).unwrap().fetch(&delta_spec).unwrap().unwrap();
            let spool_bytes = spool_fetch().payload_bytes();
            let sock_bytes = sock.fetch(&delta_spec).unwrap().unwrap().payload_bytes();
            let t_spool = time_n(3, || {
                spool_fetch();
            });
            let t_sock = time_n(3, || {
                sock.fetch(&delta_spec).unwrap().unwrap();
            });
            println!(
                "lossy   {label:>7} frac={frac:<4}: delta spool {spool_bytes:>8} B / socket {sock_bytes:>8} B \
                 ({:.2}/{:.2} ms)",
                t_spool * 1e3,
                t_sock * 1e3
            );
            spool_delta.insert(label, spool_bytes);
            for (transport, bytes, t) in
                [("spool", spool_bytes, t_spool), ("socket", sock_bytes, t_sock)]
            {
                compressed_rows.push(format!(
                    "{{\"transport\": \"{transport}\", \"changed_fraction\": {frac}, \
                     \"codec\": \"{label}\", \"plane\": \"scattered\", \
                     \"delta_payload_bytes\": {bytes}, \"fetch_delta_ms\": {}}}",
                    ms(Some(t))
                ));
            }
            std::fs::remove_dir_all(&dir).ok();
        }
        drop(server);
        let (int8, rle) = (spool_delta["int8"], spool_delta["rle"]);
        assert!(
            int8 * 2 <= rle,
            "lossy gate: int8 delta ({int8} B) must move <= half the delta+RLE bytes ({rle} B)"
        );
        println!(
            "lossy   gate: int8 delta moves {:.2}x fewer bytes than delta+RLE at frac={frac}",
            rle as f64 / int8 as f64
        );
    }

    // ---- concurrent vs serial socket fetches: N clients pulling the
    // same ~4MB plane one-after-another vs all at once. With the
    // thread-per-connection server the concurrent wall time approaches
    // the slowest single fetch; the old serial-accept server made it the
    // sum.
    let sock_concurrency = {
        let server =
            SocketServer::bind_tcp("127.0.0.1:0", 4).expect("binding concurrency bench server");
        let seeder = SocketTransport::connect_tcp(server.addr());
        seeder
            .publish(Checkpoint::from_flat(0, 1, plane.clone(), TensorMap::new()))
            .unwrap();
        let clients = 4usize;
        let t_serial = time_n(3, || {
            for _ in 0..clients {
                SocketTransport::connect_tcp(server.addr())
                    .latest(0)
                    .unwrap()
                    .unwrap();
            }
        });
        let t_concurrent = time_n(3, || {
            let addr = server.addr().to_string();
            let handles: Vec<_> = (0..clients)
                .map(|_| {
                    let addr = addr.clone();
                    std::thread::spawn(move || {
                        SocketTransport::connect_tcp(&addr).latest(0).unwrap().unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        println!(
            "socket fetch x{clients}:     serial {:>7.2} ms, concurrent {:>7.2} ms ({:.2}x)",
            t_serial * 1e3,
            t_concurrent * 1e3,
            t_serial / t_concurrent
        );
        format!(
            "{{\"clients\": {clients}, \"serial_fetch_ms\": {}, \"concurrent_fetch_ms\": {}}}",
            ms(Some(t_serial)),
            ms(Some(t_concurrent))
        )
    };

    // ---- fan-out: N concurrent readers each pulling one small (~64 KB)
    // plane to completion, direct from the hub vs through a two-relay
    // tier subscribed to the same hub. The event-driven loop serves all
    // N connections from one thread either way; the relayed rows show
    // the tree halving the hub's per-reader fan-out (each relay answers
    // its half from the local mirror). Readers use tiny stacks: the
    // point at N=512 is that neither tier spawns a thread per reader.
    let mut fanout_rows: Vec<String> = Vec::new();
    {
        let small_params = ragged_params(16_384); // 64 KB plane
        let small_layout = Arc::new(FlatLayout::from_map(&small_params, "params."));
        let small = Arc::new(FlatBuffer::gather(small_layout.clone(), &small_params).unwrap());
        let plane_bytes = small_layout.total_len() * 4;
        for readers in [8usize, 64, 512] {
            let server =
                SocketServer::bind_tcp("127.0.0.1:0", 4).expect("binding fanout bench server");
            let seeder = SocketTransport::connect_tcp(server.addr());
            seeder
                .publish(Checkpoint::from_flat(0, 1, small.clone(), TensorMap::new()))
                .unwrap();

            let fetch_all = |addrs: &[String]| -> f64 {
                let t0 = Instant::now();
                let handles: Vec<_> = (0..readers)
                    .map(|i| {
                        let addr = addrs[i % addrs.len()].clone();
                        std::thread::Builder::new()
                            .stack_size(128 * 1024)
                            .spawn(move || {
                                SocketTransport::connect_tcp(&addr).latest(0).unwrap().unwrap();
                            })
                            .unwrap()
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                t0.elapsed().as_secs_f64()
            };
            let t_direct = fetch_all(&[server.addr().to_string()]);

            // two-relay tier over the same hub; warm both mirrors before
            // timing so the rows measure mirror serving, not passthrough
            let cfg = RelayConfig {
                poll_interval: Duration::from_millis(1),
                ..RelayConfig::default()
            };
            let spawn_relay = || {
                let up: Arc<dyn ExchangeTransport> =
                    Arc::new(SocketTransport::connect_tcp(server.addr()));
                Relay::spawn_tcp(up, "127.0.0.1:0", cfg.clone()).expect("spawning bench relay")
            };
            let relays = [spawn_relay(), spawn_relay()];
            for r in &relays {
                let probe = SocketTransport::connect_tcp(r.addr());
                let t0 = Instant::now();
                while !matches!(probe.latest(0), Ok(Some(_))) {
                    assert!(t0.elapsed() < Duration::from_secs(10), "relay never warmed");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            let relay_addrs: Vec<String> =
                relays.iter().map(|r| r.addr().to_string()).collect();
            let t_relayed = fetch_all(&relay_addrs);

            let goodput = |t: f64| readers as f64 * plane_bytes as f64 / t / 1e6;
            println!(
                "fanout x{readers:<3}:            direct {:>7.2} ms ({:>7.1} MB/s), \
                 2-relay {:>7.2} ms ({:>7.1} MB/s)",
                t_direct * 1e3,
                goodput(t_direct),
                t_relayed * 1e3,
                goodput(t_relayed)
            );
            fanout_rows.push(format!(
                "{{\"readers\": {readers}, \"plane_bytes\": {plane_bytes}, \
                 \"direct_wall_ms\": {}, \"relayed_wall_ms\": {}, \
                 \"direct_goodput_mbps\": {:.1}, \"relayed_goodput_mbps\": {:.1}}}",
                ms(Some(t_direct)),
                ms(Some(t_relayed)),
                goodput(t_direct),
                goodput(t_relayed)
            ));
        }
    }

    // ---- the serving tier: flat-out open-loop goodput at several
    // micro-batch caps (rps=0 submits without pacing, so deep queues
    // actually exercise the cap — the throughput-vs-batch-size curve),
    // then the cost of a verified hot swap landing on a live server
    // (digest re-check + atomic flip + churn probe: the real install
    // path `codistill serve` pays mid-traffic).
    let mut serving_rows: Vec<String> = Vec::new();
    let serving_install_ms = {
        let snap = |steps: u64| {
            let mut m = DriftMember::with_frozen(0, 4096);
            for _ in 0..steps {
                m.train_step(0.0, 0.1).unwrap();
            }
            Arc::new(m.snapshot().unwrap())
        };
        for batch in [1usize, 16, 64, 256] {
            let srv = InferenceServer::start(
                Arc::new(MockForward::new()),
                ServeConfig {
                    max_batch_items: batch,
                    max_delay: Duration::from_millis(1),
                    workers: 2,
                    probe: vec![],
                },
            );
            srv.install(snap(1)).unwrap();
            let spec = OpenLoopSpec {
                load: LoadSpec {
                    requests: 2000,
                    seed: 7,
                    min_features: 1,
                    max_features: 4,
                },
                rps: 0.0,
            };
            let run = open_loop(&srv, &spec);
            println!(
                "serving batch={batch:>3}:       goodput {:>8.0} req/s, p50 {:>7.3} ms, p99 {:>7.3} ms",
                run.report.goodput(),
                run.report.latency.p50_s() * 1e3,
                run.report.latency.p99_s() * 1e3
            );
            serving_rows.push(format!(
                "{{\"max_batch_items\": {batch}, \"requests\": {}, \"goodput_rps\": {:.0}, \
                 \"p50_ms\": {}, \"p99_ms\": {}}}",
                run.report.sent,
                run.report.goodput(),
                ms(Some(run.report.latency.p50_s())),
                ms(Some(run.report.latency.p99_s()))
            ));
            srv.shutdown();
        }
        let srv = InferenceServer::start(Arc::new(MockForward::new()), ServeConfig::default());
        let (a, b) = (snap(3), snap(9));
        srv.install(a.clone()).unwrap();
        let mut flip = false;
        let t_install = time_n(50, || {
            flip = !flip;
            srv.install(if flip { b.clone() } else { a.clone() }).unwrap();
        });
        println!(
            "serving hot swap:        {:>8.3} ms  (digest verify + flip + churn probe)",
            t_install * 1e3
        );
        srv.shutdown();
        t_install
    };

    // ---- tensor <-> literal boundary.
    let big = Tensor::f32(&[1_048_576], vec![1.0; 1_048_576]).unwrap();
    let t_lit = time_n(50, || {
        let _ = big.to_literal().unwrap();
    });
    println!("to_literal(4 MB):        {:>8.2} ms", t_lit * 1e3);

    // ---- machine-readable trajectory.
    let json = format!(
        "{{\n  \"bench\": \"perf_hotpath\",\n  \"iters\": {iters},\n  \"sections\": {{\n    \
         \"train_step_ms\": {},\n    \
         \"teacher_predict_ms\": {},\n    \
         \"codistill_step_ms\": {},\n    \
         \"sync_group_step_ms\": {},\n    \
         \"allreduce\": [\n      {}\n    ],\n    \
         \"allreduce_scaling\": [\n      {}\n    ],\n    \
         \"flat_gather_ms\": {},\n    \
         \"flat_scatter_ms\": {},\n    \
         \"ckpt_publish_latest_ms\": {},\n    \
         \"ckpt_save_ms\": {},\n    \
         \"ckpt_load_ms\": {},\n    \
         \"transport\": [\n      {}\n    ],\n    \
         \"delta_exchange\": [\n      {}\n    ],\n    \
         \"compressed_exchange\": [\n      {}\n    ],\n    \
         \"socket_concurrency\": {},\n    \
         \"fanout\": [\n      {}\n    ],\n    \
         \"serving\": {{\n      \"throughput\": [\n        {}\n      ],\n      \
         \"hot_swap_install_ms\": {}\n    }},\n    \
         \"to_literal_ms\": {}\n  }}\n}}\n",
        ms(art.train_step),
        ms(art.teacher_predict),
        ms(art.codistill_step),
        ms(art.sync_group_step),
        allreduce_rows.join(",\n      "),
        allreduce_scaling_rows.join(",\n      "),
        ms(Some(t_gather)),
        ms(Some(t_scatter)),
        ms(Some(t_publish)),
        ms(Some(t_save)),
        ms(Some(t_load)),
        transport_rows.join(",\n      "),
        delta_rows.join(",\n      "),
        compressed_rows.join(",\n      "),
        sock_concurrency,
        fanout_rows.join(",\n      "),
        serving_rows.join(",\n        "),
        ms(Some(serving_install_ms)),
        ms(Some(t_lit)),
    );
    std::fs::write(&json_path, &json).unwrap();
    println!("wrote {json_path}");
}

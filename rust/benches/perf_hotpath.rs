//! Hot-path microbenchmarks (harness=false): the numbers behind
//! EXPERIMENTS.md §Perf.
//!
//! Measures, per layer-3 hot spot:
//!   * fused `train_step` latency (the floor set by L1/L2);
//!   * teacher `predict` latency (codistillation's extra forward pass —
//!     the paper argues this is nearly free; here we print the ratio);
//!   * allreduce strategies (naive vs tree) at LM-gradient sizes;
//!   * tensor<->literal boundary cost (runtime overhead);
//!   * explicit sync-SGD group step vs fused equivalent (coordinator
//!     overhead).

use codistill::codistill::Member;
use codistill::config::Settings;
use codistill::data::corpus::Batcher;
use codistill::data::shard::{ShardMode, ShardPlan};
use codistill::experiments::common::{corpus_for, lm_member, open_bundle};
use codistill::models::lm::{LmSyncGroup, SmoothingMode};
use codistill::runtime::{Tensor, TensorMap};
use codistill::sgd::allreduce::{allreduce_mean, ReduceStrategy};
use std::time::Instant;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() {
    let mut s = Settings::new();
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv).unwrap();
    }
    let iters = s.usize_or("iters", 10).unwrap();

    // ---- train_step + predict latency (fused member).
    let bundle = open_bundle(&s, "lm_b64").expect("artifacts missing: run make artifacts");
    let plan = ShardPlan::new(1, 64, ShardMode::Disjoint);
    let mut member = lm_member(&bundle, &plan, 0, 7, 1, SmoothingMode::None, 2).unwrap();
    member.train_step(0.0, 0.03).unwrap(); // warmup/compile
    let t_step = time_n(iters, || {
        member.train_step(0.0, 0.03).unwrap();
    });
    println!("train_step(b=64):        {:>8.2} ms", t_step * 1e3);

    let corpus = corpus_for(&bundle).unwrap();
    let streams: Vec<u64> = (500..564).collect();
    let mut batcher = Batcher::new(&corpus, 7, &streams, 16);
    let tokens = batcher.next_batch().unwrap();
    member.predict_probs(&tokens).unwrap();
    let t_pred = time_n(iters, || {
        member.predict_probs(&tokens).unwrap();
    });
    println!(
        "teacher predict(b=64):   {:>8.2} ms  ({:.0}% of a train step; paper: \"worst case ~50%\")",
        t_pred * 1e3,
        100.0 * t_pred / t_step
    );

    // ---- codistillation step (train + teacher forward).
    let mut a = lm_member(&bundle, &plan, 0, 9, 1, SmoothingMode::None, 2).unwrap();
    let b = lm_member(&bundle, &plan, 0, 9, 2, SmoothingMode::None, 2).unwrap();
    a.set_fixed_teachers(vec![std::sync::Arc::new(b.snapshot().unwrap())])
        .unwrap();
    a.train_step(1.0, 0.03).unwrap();
    let t_codist = time_n(iters, || {
        a.train_step(1.0, 0.03).unwrap();
    });
    println!(
        "codistill step(b=64):    {:>8.2} ms  ({:.2}x baseline step)",
        t_codist * 1e3,
        t_codist / t_step
    );

    // ---- allreduce strategies at paper-ish gradient sizes.
    for (workers, numel) in [(8usize, 65_536usize), (32, 65_536), (8, 1_048_576)] {
        let make = || -> Vec<TensorMap> {
            (0..workers)
                .map(|w| {
                    let mut m = TensorMap::new();
                    m.insert(
                        "grads.w",
                        Tensor::f32(&[numel], vec![w as f32; numel]).unwrap(),
                    );
                    m
                })
                .collect()
        };
        let t_naive = time_n(5, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Naive).unwrap();
        });
        let t_tree = time_n(5, || {
            allreduce_mean(make(), "grads.", ReduceStrategy::Tree).unwrap();
        });
        println!(
            "allreduce w={workers:<2} n={numel:>8}: naive {:>7.2} ms, tree {:>7.2} ms ({:.2}x)",
            t_naive * 1e3,
            t_tree * 1e3,
            t_naive / t_tree
        );
    }

    // ---- tensor <-> literal boundary.
    let big = Tensor::f32(&[1_048_576], vec![1.0; 1_048_576]).unwrap();
    let t_lit = time_n(50, || {
        let _ = big.to_literal().unwrap();
    });
    println!("to_literal(4 MB):        {:>8.2} ms", t_lit * 1e3);

    // ---- explicit allreduce group step vs fused equivalent.
    let worker_bundle = open_bundle(&s, "lm_w8").unwrap();
    let group_streams: Vec<u64> = (0..64).collect();
    let val_streams: Vec<u64> = (2_000_000..2_000_064).collect();
    let mut group = LmSyncGroup::new(
        &worker_bundle,
        &bundle,
        7,
        1,
        8,
        &group_streams,
        &val_streams,
        &corpus,
        2,
    )
    .unwrap();
    group.train_step(0.0, 0.03).unwrap();
    let t_group = time_n(iters.min(5), || {
        group.train_step(0.0, 0.03).unwrap();
    });
    println!(
        "sync group step (8x b=8):{:>8.2} ms  (coordinator overhead vs fused: {:.2}x)",
        t_group * 1e3,
        t_group / t_step
    );
}

//! cargo-bench wrapper for the `fig1` experiment (harness=false).
//!
//! Runs a scaled-down-but-representative configuration by default so the
//! whole bench suite completes in minutes; pass key=value args after
//! `cargo bench --bench fig1_sync_sgd_scaling -- ` to override (e.g. steps=600 for the
//! full EXPERIMENTS.md configuration).

use codistill::config::Settings;

fn main() {
    let mut s = Settings::new();
    for kv in ["steps=120", "eval_every=20", ] {
        s.apply(kv).unwrap();
    }
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv).unwrap();
    }
    let t0 = std::time::Instant::now();
    codistill::experiments::fig1::run(&s).expect("fig1 failed");
    println!("[bench:fig1_sync_sgd_scaling] completed in {:.1}s", t0.elapsed().as_secs_f64());
}

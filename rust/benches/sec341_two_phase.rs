//! cargo-bench wrapper for the `sec341` experiment (harness=false).
//!
//! Runs a scaled-down-but-representative configuration by default so the
//! whole bench suite completes in minutes; pass key=value args after
//! `cargo bench --bench sec341_two_phase -- ` to override (e.g. steps=600 for the
//! full EXPERIMENTS.md configuration).

use codistill::config::Settings;

fn main() {
    let mut s = Settings::new();
    for kv in ["phase1_steps=120", "phase2_steps=60", "codist_steps=180", "burn_in=40", ] {
        s.apply(kv).unwrap();
    }
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv).unwrap();
    }
    let t0 = std::time::Instant::now();
    codistill::experiments::two_phase::run(&s).expect("sec341 failed");
    println!("[bench:sec341_two_phase] completed in {:.1}s", t0.elapsed().as_secs_f64());
}

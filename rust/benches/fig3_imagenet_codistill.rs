//! cargo-bench wrapper for the `fig3` experiment (harness=false).
//!
//! Runs a scaled-down-but-representative configuration by default so the
//! whole bench suite completes in minutes; pass key=value args after
//! `cargo bench --bench fig3_imagenet_codistill -- ` to override (e.g. steps=600 for the
//! full EXPERIMENTS.md configuration).

use codistill::config::Settings;

fn main() {
    let mut s = Settings::new();
    for kv in ["steps=200", "eval_every=25", "burn_in=60", ] {
        s.apply(kv).unwrap();
    }
    for kv in std::env::args().skip(1).filter(|a| a.contains('=')) {
        s.apply(&kv).unwrap();
    }
    let t0 = std::time::Instant::now();
    codistill::experiments::fig3::run(&s).expect("fig3 failed");
    println!("[bench:fig3_imagenet_codistill] completed in {:.1}s", t0.elapsed().as_secs_f64());
}

"""Layer-2: the Criteo click-through-rate DNN (paper §3.1, Table 1).

Paper architecture: feed-forward ReLU net, hidden sizes 2560/1024/256,
logistic output, Adagrad lr 0.001, inputs = 13 integer + 26 categorical
features. Scaled default here is 256/128/64 (configurable; the Table 1
claim is about *relative churn between retrains*, which survives scaling).

Categorical features are hash-bucketed on the Rust side into
``[0, buckets)`` per field; the model owns one embedding table per field
(stored as a single ``[26*buckets, dim]`` matrix, indexed with per-field
offsets).

Binary losses reuse the vocabulary kernels via the 2-class embedding
``sigmoid(z) = softmax([0, z])[1]``: hard loss = softmax_xent on 2-class
logits, distillation loss = distill_xent against ``[1-p_t, p_t]`` — so the
Criteo path exercises the exact same Layer-1 kernels as the LM.
"""

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import adagrad_update, distill_xent, matmul, softmax_xent

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class CriteoConfig:
    n_dense: int = 13
    n_cat: int = 26
    buckets: int = 1000  # hash buckets per categorical field
    cat_dim: int = 8
    hidden1: int = 256
    hidden2: int = 128
    hidden3: int = 64
    batch: int = 256

    def meta(self) -> Dict[str, str]:
        return {
            "model": "criteo",
            "n_dense": str(self.n_dense),
            "n_cat": str(self.n_cat),
            "buckets": str(self.buckets),
            "cat_dim": str(self.cat_dim),
            "hidden1": str(self.hidden1),
            "hidden2": str(self.hidden2),
            "hidden3": str(self.hidden3),
            "batch": str(self.batch),
            "optimizer": "adagrad",
        }

    @property
    def mlp_in(self) -> int:
        return self.n_dense + self.n_cat * self.cat_dim


# ------------------------------------------------------------------- params


def init_params(cfg: CriteoConfig, seed) -> Params:
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    keys = jax.random.split(key, 5)
    dims = [cfg.mlp_in, cfg.hidden1, cfg.hidden2, cfg.hidden3, 1]
    params: Params = {
        "emb": jax.random.normal(keys[0], (cfg.n_cat * cfg.buckets, cfg.cat_dim)) * 0.05,
    }
    for i in range(4):
        lim = jnp.sqrt(6.0 / (dims[i] + dims[i + 1]))
        params[f"fc{i}"] = {
            "w": jax.random.uniform(keys[i + 1], (dims[i], dims[i + 1]), minval=-lim, maxval=lim),
            "b": jnp.zeros((dims[i + 1],)),
        }
    return params


def init_opt(params: Params):
    """Adagrad accumulator per leaf (paper: Adagrad, lr 0.001)."""
    return {"acc": jax.tree_util.tree_map(lambda p: jnp.full(p.shape, 0.1), params)}


# ------------------------------------------------------------------ forward


def forward(cfg: CriteoConfig, params: Params, dense, cat_idx):
    """dense: [B, 13] f32 (already log-normalized on the Rust side);
    cat_idx: [B, 26] i32 in [0, buckets). Returns logits [B]."""
    offsets = (jnp.arange(cfg.n_cat, dtype=jnp.int32) * cfg.buckets)[None, :]
    emb = jnp.take(params["emb"], cat_idx + offsets, axis=0)  # [B, 26, D]
    x = jnp.concatenate([dense, emb.reshape(dense.shape[0], -1)], axis=-1)
    for i in range(3):
        p = params[f"fc{i}"]
        x = jax.nn.relu(matmul(x, p["w"]) + p["b"])
    p = params["fc3"]
    return (matmul(x, p["w"]) + p["b"])[:, 0]  # [B]


def _two_class(logits):
    """[B] -> [B, 2] logits such that softmax(.)[1] == sigmoid(logits)."""
    return jnp.stack([jnp.zeros_like(logits), logits], axis=-1)


def loss_fn(cfg, params, dense, cat_idx, labels, teacher_p, distill_w):
    logits = forward(cfg, params, dense, cat_idx)
    z2 = _two_class(logits)
    hard = jnp.mean(softmax_xent(z2, labels))
    soft_targets = jnp.stack([1.0 - teacher_p, teacher_p], axis=-1)
    soft = jnp.mean(distill_xent(z2, soft_targets))
    return hard + distill_w * soft, (hard, soft)


# -------------------------------------------------------------- executables


def _zeros_like_tree(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def _example_params(cfg):
    shapes = jax.eval_shape(lambda s: init_params(cfg, s), jnp.zeros((), jnp.int32))
    return _zeros_like_tree(shapes)


def example_batch(cfg: CriteoConfig):
    return {
        "dense": jnp.zeros((cfg.batch, cfg.n_dense)),
        "cat_idx": jnp.zeros((cfg.batch, cfg.n_cat), jnp.int32),
        "labels": jnp.zeros((cfg.batch,), jnp.int32),
        "teacher_p": jnp.zeros((cfg.batch,)),
    }


def export_init(cfg: CriteoConfig):
    def fn(seed):
        return {"params": init_params(cfg, seed)}

    return fn, {"seed": jnp.zeros((), jnp.int32)}


def export_train_step(cfg: CriteoConfig):
    def fn(params, opt, dense, cat_idx, labels, teacher_p, distill_w, lr):
        (_, (hard, soft)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, dense, cat_idx, labels, teacher_p, distill_w),
            has_aux=True,
        )(params)
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_a = jax.tree_util.tree_flatten(opt["acc"])[0]
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        new_p, new_a = [], []
        for p, a, g in zip(flat_p, flat_a, flat_g):
            p2, a2 = adagrad_update(p, a, g, lr)
            new_p.append(p2)
            new_a.append(a2)
        unf = jax.tree_util.tree_unflatten
        return {
            "params": unf(treedef, new_p),
            "opt": {"acc": unf(treedef, new_a)},
            "loss": hard,
            "distill_loss": soft,
        }

    params = _example_params(cfg)
    batch = example_batch(cfg)
    return fn, {
        "params": params,
        "opt": {"acc": _zeros_like_tree(params)},
        **batch,
        "distill_w": jnp.zeros(()),
        "lr": jnp.zeros(()),
    }


def export_predict(cfg: CriteoConfig):
    """CTR probabilities — used both as the codistillation teacher signal
    and by the churn evaluator (mean |Δp| between retrains, Table 1)."""

    def fn(params, dense, cat_idx):
        return {"probs": jax.nn.sigmoid(forward(cfg, params, dense, cat_idx))}

    params = _example_params(cfg)
    b = example_batch(cfg)
    return fn, {"params": params, "dense": b["dense"], "cat_idx": b["cat_idx"]}


def export_eval(cfg: CriteoConfig):
    """Validation log loss (summed; Rust accumulates over batches)."""

    def fn(params, dense, cat_idx, labels):
        logits = forward(cfg, params, dense, cat_idx)
        xent = softmax_xent(_two_class(logits), labels)
        return {
            "sum_loss": jnp.sum(xent),
            "count": jnp.asarray(xent.shape[0], jnp.float32),
        }

    params = _example_params(cfg)
    b = example_batch(cfg)
    return fn, {
        "params": params,
        "dense": b["dense"],
        "cat_idx": b["cat_idx"],
        "labels": b["labels"],
    }


EXPORTS = {
    "init": export_init,
    "train_step": export_train_step,
    "predict": export_predict,
    "eval": export_eval,
}

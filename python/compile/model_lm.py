"""Layer-2: the paper's Common Crawl language model.

An RNN LM with ``n_layers`` LayerNorm-LSTM layers (Ba et al. 2016), tied to
the paper's §3.1 architecture (2×LSTM-1024 + LN, 256-dim embeddings, word
pieces, Adam) but dimensionally scaled for the CPU-PJRT testbed — all dims
come from :class:`LmConfig` and the artifact bundles record them.

Semantics preserved from the paper:

* hidden state is **carried across batches** ("saving hidden state across
  batches"); the state is an explicit input/output of every executable and
  the Rust coordinator owns it per data stream;
* the state never gets reset by the pipeline — the model sees an
  end-of-document token and the forward pass resets h/c *at* EOD positions,
  so "the model has to learn to use the end of document token to reset
  itself" is replaced by an explicit, testable reset (documented
  simplification: at our scale learned resets don't emerge reliably);
* the training loss is phi + psi: hard cross entropy plus the distillation
  cross entropy against teacher soft targets, with the distillation weight
  a runtime input so one artifact serves plain SGD (w=0), codistillation,
  and both label-smoothing baselines of Fig 2a;
* Adam, as in all Common Crawl experiments in the paper.

All dense compute lowers through the Layer-1 Pallas kernels.
"""

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import (
    adam_update,
    distill_xent,
    layernorm,
    lstm_gates,
    matmul,
    softmax_xent,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LmConfig:
    """Static dimensions baked into one artifact bundle."""

    vocab: int = 512
    embed: int = 32
    hidden: int = 64
    layers: int = 2
    batch: int = 64
    unroll: int = 16  # T: tokens per stream per step (paper: 32)
    eod_id: int = 1  # end-of-document token id (0 is reserved for OOV)

    def meta(self) -> Dict[str, str]:
        return {
            "model": "lm",
            "vocab": str(self.vocab),
            "embed": str(self.embed),
            "hidden": str(self.hidden),
            "layers": str(self.layers),
            "batch": str(self.batch),
            "unroll": str(self.unroll),
            "eod_id": str(self.eod_id),
            "optimizer": "adam",
        }


# ------------------------------------------------------------------- params


def init_params(cfg: LmConfig, seed) -> Params:
    """Initialize parameters from a scalar seed (lowered into `init`).

    Glorot-uniform matrices, +1 forget-gate bias (standard LSTM practice),
    unit LN gain.
    """
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    keys = jax.random.split(key, 2 + cfg.layers)
    params: Params = {
        "embedding": jax.random.normal(keys[0], (cfg.vocab, cfg.embed)) * 0.05,
    }
    for l in range(cfg.layers):
        fan_in = (cfg.embed if l == 0 else cfg.hidden) + cfg.hidden
        fan_out = 4 * cfg.hidden
        lim = jnp.sqrt(6.0 / (fan_in + fan_out))
        w = jax.random.uniform(keys[1 + l], (fan_in, fan_out), minval=-lim, maxval=lim)
        b = jnp.zeros((fan_out,))
        # forget-gate bias +1: gates ordered (i, f, g, o)
        b = b.at[cfg.hidden : 2 * cfg.hidden].set(1.0)
        params[f"layer{l}"] = {
            "w": w,
            "b": b,
            "ln_gain": jnp.ones((fan_out,)),
            "ln_bias": jnp.zeros((fan_out,)),
        }
    lim = jnp.sqrt(6.0 / (cfg.hidden + cfg.vocab))
    params["out"] = {
        "w": jax.random.uniform(keys[-1], (cfg.hidden, cfg.vocab), minval=-lim, maxval=lim),
        "b": jnp.zeros((cfg.vocab,)),
    }
    return params


def init_state(cfg: LmConfig) -> Dict[str, jnp.ndarray]:
    """Zero RNN state: h/c stacked over layers, [L, B, H]."""
    shape = (cfg.layers, cfg.batch, cfg.hidden)
    return {"h": jnp.zeros(shape), "c": jnp.zeros(shape)}


def init_opt(params: Params) -> Dict[str, Any]:
    """Adam state: first/second moments per leaf + step counter."""
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "step": jnp.zeros(())}


# ------------------------------------------------------------------ forward


def _step_cell(cfg: LmConfig, params: Params, l: int, x, h, c):
    p = params[f"layer{l}"]
    xa = jnp.concatenate([x, h], axis=-1)
    pre = matmul(xa, p["w"]) + p["b"]
    pre = layernorm(pre, p["ln_gain"], p["ln_bias"])
    return lstm_gates(pre, c)


def forward(cfg: LmConfig, params: Params, state, tokens):
    """Run the LM over one unroll.

    tokens: [B, T+1] i32 — inputs are tokens[:, :-1], targets tokens[:, 1:].
    Returns (logits [T*B, V], targets [T*B], new_state).
    """
    inputs = tokens[:, :-1]  # [B, T]
    targets = tokens[:, 1:]  # [B, T]
    emb = jnp.take(params["embedding"], inputs, axis=0)  # [B, T, E]
    emb_t = jnp.transpose(emb, (1, 0, 2))  # [T, B, E]
    inputs_t = jnp.transpose(inputs, (1, 0))  # [T, B]

    def scan_step(carry, xs):
        h, c = carry  # [L, B, H] each
        x_t, tok_t = xs  # [B, E], [B]
        # EOD reset: zero the state before consuming an EOD token.
        keep = (tok_t != cfg.eod_id).astype(jnp.float32)[None, :, None]
        h = h * keep
        c = c * keep
        new_h = []
        new_c = []
        inp = x_t
        for l in range(cfg.layers):
            hl, cl = _step_cell(cfg, params, l, inp, h[l], c[l])
            new_h.append(hl)
            new_c.append(cl)
            inp = hl
        return (jnp.stack(new_h), jnp.stack(new_c)), inp  # top-layer h out

    (h_fin, c_fin), tops = jax.lax.scan(
        scan_step, (state["h"], state["c"]), (emb_t, inputs_t)
    )
    # tops: [T, B, H]
    t, b, hd = tops.shape
    logits = matmul(tops.reshape(t * b, hd), params["out"]["w"]) + params["out"]["b"]
    new_state = {"h": jax.lax.stop_gradient(h_fin), "c": jax.lax.stop_gradient(c_fin)}
    return logits, targets.transpose(1, 0).reshape(t * b), new_state


# ------------------------------------------------------------------- losses


def loss_fn(cfg: LmConfig, params, state, tokens, teacher_probs, distill_w):
    """phi + w·psi. teacher_probs: [T*B, V] in the same flattened layout as
    the logits (time-major)."""
    logits, targets, new_state = forward(cfg, params, state, tokens)
    hard = jnp.mean(softmax_xent(logits, targets))
    soft = jnp.mean(distill_xent(logits, teacher_probs))
    return hard + distill_w * soft, (hard, soft, new_state)


# -------------------------------------------------------------- executables
#
# Each ``export_*`` returns (fn, example_args: dict of name->pytree). aot.py
# lowers fn(*example_args.values()) and derives the spec from the pytrees.


def _example_params(cfg: LmConfig) -> Params:
    return jax.eval_shape(lambda s: init_params(cfg, s), jnp.zeros((), jnp.int32))


def _zeros_like_tree(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def example_inputs(cfg: LmConfig):
    params = _zeros_like_tree(_example_params(cfg))
    state = init_state(cfg)
    tokens = jnp.zeros((cfg.batch, cfg.unroll + 1), jnp.int32)
    probs = jnp.zeros((cfg.unroll * cfg.batch, cfg.vocab))
    return params, state, tokens, probs


def export_init(cfg: LmConfig):
    def fn(seed):
        return {"params": init_params(cfg, seed)}

    return fn, {"seed": jnp.zeros((), jnp.int32)}


def export_grad(cfg: LmConfig):
    """Per-worker gradient computation (the allreduce path)."""

    def fn(params, state, tokens, teacher_probs, distill_w):
        (loss, (hard, soft, new_state)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, state, tokens, teacher_probs, distill_w),
            has_aux=True,
        )(params)
        return {
            "grads": grads,
            "state": new_state,
            "loss": hard,
            "distill_loss": soft,
        }

    params, state, tokens, probs = example_inputs(cfg)
    return fn, {
        "params": params,
        "state": state,
        "tokens": tokens,
        "teacher_probs": probs,
        "distill_w": jnp.zeros(()),
    }


def export_apply(cfg: LmConfig):
    """Adam apply step for reduced gradients (the allreduce path)."""

    def fn(params, opt, grads, lr):
        step = opt["step"] + 1.0
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_m = jax.tree_util.tree_flatten(opt["m"])[0]
        flat_v = jax.tree_util.tree_flatten(opt["v"])[0]
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        new_p, new_m, new_v = [], [], []
        for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g):
            p2, m2, v2 = adam_update(p, m, v, g, lr, step)
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        unf = jax.tree_util.tree_unflatten
        return {
            "params": unf(treedef, new_p),
            "opt": {"m": unf(treedef, new_m), "v": unf(treedef, new_v), "step": step},
        }

    params, _, _, _ = example_inputs(cfg)
    opt = {
        "m": _zeros_like_tree(params),
        "v": _zeros_like_tree(params),
        "step": jnp.zeros(()),
    }
    return fn, {
        "params": params,
        "opt": opt,
        "grads": _zeros_like_tree(params),
        "lr": jnp.zeros(()),
    }


def export_train_step(cfg: LmConfig):
    """Fused grad+apply at the full (effective) batch — the fast path used
    when a sync-SGD group is simulated as one large-batch step."""

    grad_fn, _ = export_grad(cfg)
    apply_fn, _ = export_apply(cfg)

    def fn(params, opt, state, tokens, teacher_probs, distill_w, lr):
        g = grad_fn(params, state, tokens, teacher_probs, distill_w)
        upd = apply_fn(params, opt, g["grads"], lr)
        return {
            "params": upd["params"],
            "opt": upd["opt"],
            "state": g["state"],
            "loss": g["loss"],
            "distill_loss": g["distill_loss"],
        }

    params, state, tokens, probs = example_inputs(cfg)
    opt = {
        "m": _zeros_like_tree(params),
        "v": _zeros_like_tree(params),
        "step": jnp.zeros(()),
    }
    return fn, {
        "params": params,
        "opt": opt,
        "state": state,
        "tokens": tokens,
        "teacher_probs": probs,
        "distill_w": jnp.zeros(()),
        "lr": jnp.zeros(()),
    }


def export_predict(cfg: LmConfig):
    """Teacher forward pass: softmax probabilities for distillation.

    Output layout matches the logits flattening ([T*B, V], time-major) so
    the Rust side can feed them straight back as ``teacher_probs``.
    """

    def fn(params, state, tokens):
        logits, _, new_state = forward(cfg, params, state, tokens)
        return {"probs": jax.nn.softmax(logits, axis=-1), "state": new_state}

    params, state, tokens, _ = example_inputs(cfg)
    return fn, {"params": params, "state": state, "tokens": tokens}


def export_eval(cfg: LmConfig):
    """Validation: summed token cross entropy + count (Rust accumulates)."""

    def fn(params, state, tokens):
        logits, targets, new_state = forward(cfg, params, state, tokens)
        xent = softmax_xent(logits, targets)
        return {
            "sum_loss": jnp.sum(xent),
            "count": jnp.asarray(xent.shape[0], jnp.float32),
            "state": new_state,
        }

    params, state, tokens, _ = example_inputs(cfg)
    return fn, {"params": params, "state": state, "tokens": tokens}


EXPORTS = {
    "init": export_init,
    "grad": export_grad,
    "apply": export_apply,
    "train_step": export_train_step,
    "predict": export_predict,
    "eval": export_eval,
}

"""Pure-jnp oracles for every Pallas kernel.

Each function here is the semantic ground truth the corresponding kernel in
this package must match (values and gradients). The pytest suite in
``python/tests/`` asserts ``assert_allclose(kernel(...), ref(...))`` across a
hypothesis-driven sweep of shapes and seeds.

Everything is plain differentiable jnp so ``jax.grad`` through a ref is the
gradient oracle for the kernels' ``custom_vjp`` implementations.
"""

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- matmul


def matmul(x, y):
    """Row-major [m,k] @ [k,n] in f32."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


# ------------------------------------------------------------------ layernorm


def layernorm(x, gain, bias, eps=1e-5):
    """Layer normalization over the last dimension.

    x: [..., d], gain/bias: [d].
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    return xhat * gain + bias


# ----------------------------------------------------------------- lstm gates


def lstm_gates(preact, c_prev):
    """Fused LSTM gate nonlinearities + cell update.

    preact: [b, 4h] pre-activations ordered (i, f, g, o); c_prev: [b, h].
    Returns (h_new, c_new).
    """
    h = c_prev.shape[-1]
    i = jax.nn.sigmoid(preact[..., 0 * h : 1 * h])
    f = jax.nn.sigmoid(preact[..., 1 * h : 2 * h])
    g = jnp.tanh(preact[..., 2 * h : 3 * h])
    o = jax.nn.sigmoid(preact[..., 3 * h : 4 * h])
    c_new = f * c_prev + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


# --------------------------------------------------------------- softmax xent


def softmax_xent(logits, labels):
    """Per-example cross entropy with integer labels.

    logits: [b, v] f32; labels: [b] i32. Returns [b] f32.
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - picked


# --------------------------------------------------------------- distill xent


def distill_xent(logits, teacher_probs):
    """Soft-target cross entropy: -sum_v p_t[v] * log_softmax(z)[v].

    This is the paper's distillation loss psi with the teacher predictive
    distribution as soft targets. teacher_probs need not be normalized
    (label-smoothing baselines pass scaled distributions); the general
    gradient uses sum_p. Returns [b] f32.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(teacher_probs * logp, axis=-1)


# ------------------------------------------------------------------ optimizer


def adam_update(p, m, v, g, lr, beta1, beta2, eps, step):
    """One fused Adam update. step counts from 1."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
    mhat = m_new / (1.0 - beta1**step)
    vhat = v_new / (1.0 - beta2**step)
    p_new = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p_new, m_new, v_new


def adagrad_update(p, acc, g, lr, eps):
    """One fused Adagrad update (paper uses Adagrad on Criteo)."""
    acc_new = acc + jnp.square(g)
    p_new = p - lr * g / (jnp.sqrt(acc_new) + eps)
    return p_new, acc_new


def momentum_update(p, vel, g, lr, mu):
    """Heavy-ball momentum (Goyal et al. ImageNet setup)."""
    vel_new = mu * vel + g
    p_new = p - lr * vel_new
    return p_new, vel_new


# ------------------------------------------------- composed lstm cell (L2 ref)


def lstm_cell(x, h_prev, c_prev, w, b, ln_gain, ln_bias):
    """Reference composed LayerNorm-LSTM cell.

    x: [b, e], h_prev/c_prev: [b, h], w: [e+h, 4h], b: [4h],
    ln_gain/ln_bias: [4h] applied to the fused gate pre-activations.
    """
    xa = jnp.concatenate([x, h_prev], axis=-1)
    pre = matmul(xa, w) + b
    pre = layernorm(pre, ln_gain, ln_bias)
    return lstm_gates(pre, c_prev)

"""LayerNorm Pallas kernel (last-dim normalization) with custom VJP.

The paper's LM uses LayerNorm-LSTM (Ba et al., 2016); this kernel
normalizes the fused gate pre-activations. The grid tiles rows; each block
holds ``(bb, d)`` so the full feature dimension is VMEM-resident (d is at
most 4*hidden = a few thousand floats, far under budget) and the mean/var
reduction happens entirely on-chip.

Backward uses the closed form: with xhat = (x-mu)/std and dxh = dy * gain,
  dx = (dxh - mean(dxh) - xhat * mean(dxh * xhat)) / std.
dgain/dbias are row-reductions computed by a second Pallas kernel that
accumulates over the row-block grid axis.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

EPS = 1e-5
DEFAULT_BB = 128


def _ln_fwd_kernel(x_ref, gain_ref, bias_ref, y_ref, xhat_ref, rstd_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * rstd
    y_ref[...] = xhat * gain_ref[...] + bias_ref[...]
    xhat_ref[...] = xhat
    rstd_ref[...] = rstd[:, 0]


def _ln_fwd(x, gain, bias, bb=DEFAULT_BB):
    b, d = x.shape
    bb = pick_block(b, bb)
    grid = (b // bb,)
    return pl.pallas_call(
        _ln_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, gain, bias)


def _ln_bwd_dx_kernel(dy_ref, xhat_ref, rstd_ref, gain_ref, dx_ref):
    dy = dy_ref[...]
    xhat = xhat_ref[...]
    dxh = dy * gain_ref[...]
    m1 = jnp.mean(dxh, axis=-1, keepdims=True)
    m2 = jnp.mean(dxh * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (dxh - m1 - xhat * m2) * rstd_ref[...][:, None]


def _ln_bwd_dparams_kernel(dy_ref, xhat_ref, dgain_ref, dbias_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dgain_ref[...] = jnp.zeros_like(dgain_ref)
        dbias_ref[...] = jnp.zeros_like(dbias_ref)

    dy = dy_ref[...]
    dgain_ref[...] += jnp.sum(dy * xhat_ref[...], axis=0)
    dbias_ref[...] += jnp.sum(dy, axis=0)


def _ln_bwd(res, dy, bb=DEFAULT_BB):
    xhat, rstd, gain = res
    b, d = xhat.shape
    bb = pick_block(b, bb)
    grid = (b // bb,)
    dx = pl.pallas_call(
        _ln_bwd_dx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=INTERPRET,
    )(dy, xhat, rstd, gain)
    dgain, dbias = pl.pallas_call(
        _ln_bwd_dparams_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        interpret=INTERPRET,
    )(dy, xhat)
    return dx, dgain, dbias


@jax.custom_vjp
def layernorm(x, gain, bias):
    """Differentiable LayerNorm over the last dim. x: [b,d], gain/bias: [d]."""
    y, _, _ = _ln_fwd(x, gain, bias)
    return y


def _layernorm_fwd(x, gain, bias):
    y, xhat, rstd = _ln_fwd(x, gain, bias)
    return y, (xhat, rstd, gain)


def _layernorm_bwd(res, dy):
    return _ln_bwd(res, dy)


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)

"""Fused elementwise optimizer-update Pallas kernels.

One kernel launch per parameter tensor per step; parameters are flattened
to 1-D and tiled in VMEM-sized chunks. These sit outside the
differentiated region (they consume gradients), so no custom VJP is
needed.

Paper-matching optimizers:
  * Adam      — Common Crawl LM (Kingma & Ba; paper §3.1)
  * Adagrad   — Criteo DNN, lr 0.001 (paper §3.1)
  * Momentum  — ImageNet / Goyal et al. setup (paper §3.1)

Dynamic hyperparameters (lr, bias-correction step) enter as small f32
vectors broadcast to every block; static ones (betas, eps, mu) are baked
into the kernel closure at lowering time.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

DEFAULT_BLOCK = 4096

ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.999
ADAM_EPS = 1e-8
ADAGRAD_EPS = 1e-10


def _flatten(t):
    return t.reshape(-1)


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, sched_ref, p_out, m_out, v_out, *, beta1, beta2, eps):
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    # sched = [lr, 1/(1-beta1^t), 1/(1-beta2^t)]
    lr = sched_ref[0]
    mhat = m * sched_ref[1]
    vhat = v * sched_ref[2]
    p_out[...] = p_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    m_out[...] = m
    v_out[...] = v


def adam_update(p, m, v, g, lr, step, beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS):
    """Fused Adam. ``step`` is a traced f32 scalar counting from 1.

    Shapes are preserved; internally flattened and tiled.
    """
    shape = p.shape
    pf, mf, vf, gf = _flatten(p), _flatten(m), _flatten(v), _flatten(g)
    n = pf.shape[0]
    blk = pick_block(n, DEFAULT_BLOCK)
    grid = (n // blk,)
    sched = jnp.stack(
        [
            lr,
            1.0 / (1.0 - beta1**step),
            1.0 / (1.0 - beta2**step),
        ]
    )
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    svec = pl.BlockSpec((3,), lambda i: (0,))
    p2, m2, v2 = pl.pallas_call(
        functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps),
        grid=grid,
        in_specs=[vec, vec, vec, vec, svec],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=INTERPRET,
    )(pf, mf, vf, gf, sched)
    return p2.reshape(shape), m2.reshape(shape), v2.reshape(shape)


def _adagrad_kernel(p_ref, acc_ref, g_ref, lr_ref, p_out, acc_out, *, eps):
    g = g_ref[...]
    acc = acc_ref[...] + g * g
    p_out[...] = p_ref[...] - lr_ref[0] * g / (jnp.sqrt(acc) + eps)
    acc_out[...] = acc


def adagrad_update(p, acc, g, lr, eps=ADAGRAD_EPS):
    """Fused Adagrad (paper's Criteo optimizer)."""
    shape = p.shape
    pf, accf, gf = _flatten(p), _flatten(acc), _flatten(g)
    n = pf.shape[0]
    blk = pick_block(n, DEFAULT_BLOCK)
    grid = (n // blk,)
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    svec = pl.BlockSpec((1,), lambda i: (0,))
    p2, acc2 = pl.pallas_call(
        functools.partial(_adagrad_kernel, eps=eps),
        grid=grid,
        in_specs=[vec, vec, vec, svec],
        out_specs=[vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 2,
        interpret=INTERPRET,
    )(pf, accf, gf, jnp.reshape(lr, (1,)))
    return p2.reshape(shape), acc2.reshape(shape)


def _momentum_kernel(p_ref, vel_ref, g_ref, lr_ref, p_out, vel_out, *, mu):
    vel = mu * vel_ref[...] + g_ref[...]
    p_out[...] = p_ref[...] - lr_ref[0] * vel
    vel_out[...] = vel


def momentum_update(p, vel, g, lr, mu=0.9):
    """Fused heavy-ball momentum (Goyal et al. ImageNet setup)."""
    shape = p.shape
    pf, velf, gf = _flatten(p), _flatten(vel), _flatten(g)
    n = pf.shape[0]
    blk = pick_block(n, DEFAULT_BLOCK)
    grid = (n // blk,)
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    svec = pl.BlockSpec((1,), lambda i: (0,))
    p2, vel2 = pl.pallas_call(
        functools.partial(_momentum_kernel, mu=mu),
        grid=grid,
        in_specs=[vec, vec, vec, svec],
        out_specs=[vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 2,
        interpret=INTERPRET,
    )(pf, velf, gf, jnp.reshape(lr, (1,)))
    return p2.reshape(shape), vel2.reshape(shape)

"""Shared helpers for the Pallas kernels.

All kernels in this package are lowered with ``interpret=True``: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so interpret mode is the
correctness path and TPU performance is estimated from the BlockSpec
structure (see DESIGN.md §Hardware-Adaptation and §Perf).

Block-size policy: target MXU-aligned tiles (multiples of 8 sublanes ×
128 lanes) but never exceed the actual dimension; fall back to the largest
divisor so that grids always tile shapes exactly (our model dims are powers
of two, so in practice blocks stay aligned).
"""

INTERPRET = True

# VMEM budget per core used for the §Perf estimates (bytes). Matches a
# TPUv4-style 16 MiB scratchpad with headroom for double buffering.
VMEM_BUDGET = 16 * 1024 * 1024


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target``.

    Guarantees exact tiling (pallas BlockSpec grids must cover the array).
    For power-of-two dims this returns min(dim, largest power-of-two
    <= target), keeping tiles MXU-aligned.
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


def vmem_bytes(*block_shapes, dtype_bytes: int = 4) -> int:
    """Approximate VMEM residency of a kernel invocation: the sum of its
    input/output blocks (double-buffered pipelines double this; reported
    as-is and interpreted in DESIGN.md §Perf)."""
    total = 0
    for shape in block_shapes:
        n = dtype_bytes
        for d in shape:
            n *= d
        total += n
    return total

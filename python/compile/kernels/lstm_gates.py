"""Fused LSTM gate nonlinearity + cell-update Pallas kernel with custom VJP.

Consumes the (already layer-normalized) gate pre-activations ``[b, 4h]``
ordered (i, f, g, o) and the previous cell state ``[b, h]``; produces the
new hidden and cell states. Everything is elementwise, so the grid tiles
rows and the full gate width stays in VMEM.

The forward kernel also emits the post-nonlinearity gates (i, f, g, o
concatenated) and tanh(c_new) as residuals so the backward kernel never
recomputes transcendental functions — on TPU this trades a small VMEM/HBM
footprint for VPU throughput, the same trade the paper's training stack
makes by checkpointing activations.

Backward (denote tc = tanh(c_new)):
  do = dh * tc            dtc = dh * o      dc = dc_in + dtc * (1 - tc^2)
  di = dc * g   dg = dc * i   df = dc * c_prev   dc_prev = dc * f
  dpre_i = di * i(1-i)    dpre_f = df * f(1-f)
  dpre_g = dg * (1-g^2)   dpre_o = do * o(1-o)
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

DEFAULT_BB = 128


def _split4(a, h):
    return a[..., 0 * h : 1 * h], a[..., 1 * h : 2 * h], a[..., 2 * h : 3 * h], a[..., 3 * h : 4 * h]


def _gates_fwd_kernel(pre_ref, c_prev_ref, h_ref, c_ref, gates_ref, tc_ref):
    pre = pre_ref[...]
    c_prev = c_prev_ref[...]
    h = c_prev.shape[-1]
    zi, zf, zg, zo = _split4(pre, h)
    i = jax.nn.sigmoid(zi)
    f = jax.nn.sigmoid(zf)
    g = jnp.tanh(zg)
    o = jax.nn.sigmoid(zo)
    c_new = f * c_prev + i * g
    tc = jnp.tanh(c_new)
    h_ref[...] = o * tc
    c_ref[...] = c_new
    gates_ref[...] = jnp.concatenate([i, f, g, o], axis=-1)
    tc_ref[...] = tc


def _gates_fwd(pre, c_prev, bb=DEFAULT_BB):
    b, h4 = pre.shape
    h = c_prev.shape[-1]
    assert h4 == 4 * h, f"preact width {h4} != 4*hidden {h}"
    bb = pick_block(b, bb)
    grid = (b // bb,)
    row4 = pl.BlockSpec((bb, h4), lambda i: (i, 0))
    row1 = pl.BlockSpec((bb, h), lambda i: (i, 0))
    return pl.pallas_call(
        _gates_fwd_kernel,
        grid=grid,
        in_specs=[row4, row1],
        out_specs=[row1, row1, row4, row1],
        out_shape=[
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h4), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=INTERPRET,
    )(pre, c_prev)


def _gates_bwd_kernel(gates_ref, tc_ref, c_prev_ref, dh_ref, dc_in_ref, dpre_ref, dc_prev_ref):
    gates = gates_ref[...]
    h = tc_ref.shape[-1]
    i, f, g, o = _split4(gates, h)
    tc = tc_ref[...]
    dh = dh_ref[...]
    do = dh * tc
    dc = dc_in_ref[...] + dh * o * (1.0 - tc * tc)
    di = dc * g
    dg = dc * i
    df = dc * c_prev_ref[...]
    dc_prev_ref[...] = dc * f
    dpre_ref[...] = jnp.concatenate(
        [
            di * i * (1.0 - i),
            df * f * (1.0 - f),
            dg * (1.0 - g * g),
            do * o * (1.0 - o),
        ],
        axis=-1,
    )


def _gates_bwd(res, grads, bb=DEFAULT_BB):
    gates, tc, c_prev = res
    dh, dc_in = grads
    b, h4 = gates.shape
    h = h4 // 4
    bb = pick_block(b, bb)
    grid = (b // bb,)
    row4 = pl.BlockSpec((bb, h4), lambda i: (i, 0))
    row1 = pl.BlockSpec((bb, h), lambda i: (i, 0))
    dpre, dc_prev = pl.pallas_call(
        _gates_bwd_kernel,
        grid=grid,
        in_specs=[row4, row1, row1, row1, row1],
        out_specs=[row4, row1],
        out_shape=[
            jax.ShapeDtypeStruct((b, h4), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=INTERPRET,
    )(gates, tc, c_prev, dh, dc_in)
    return dpre, dc_prev


@jax.custom_vjp
def lstm_gates(pre, c_prev):
    """Differentiable fused LSTM gates. Returns (h_new, c_new)."""
    h, c, _, _ = _gates_fwd(pre, c_prev)
    return h, c


def _lstm_gates_fwd(pre, c_prev):
    h, c, gates, tc = _gates_fwd(pre, c_prev)
    return (h, c), (gates, tc, c_prev)


def _lstm_gates_bwd(res, grads):
    return _gates_bwd(res, grads)


lstm_gates.defvjp(_lstm_gates_fwd, _lstm_gates_bwd)

"""Layer-1 Pallas kernels for the codistillation stack.

Every kernel is lowered in interpret mode (CPU-PJRT executable HLO) and has
a pure-jnp oracle in :mod:`ref` plus hypothesis-driven tests under
``python/tests/``. Kernels that sit inside the differentiated region carry
``jax.custom_vjp`` with explicit backward kernels — interpret-mode
``pallas_call`` does not support reverse-mode autodiff.
"""

from .distill_xent import distill_xent
from .layernorm import layernorm
from .lstm_gates import lstm_gates
from .matmul import matmul
from .optim import adagrad_update, adam_update, momentum_update
from .softmax_xent import softmax_xent

__all__ = [
    "adagrad_update",
    "adam_update",
    "distill_xent",
    "layernorm",
    "lstm_gates",
    "matmul",
    "momentum_update",
    "softmax_xent",
]

"""Fused softmax cross-entropy Pallas kernel (integer labels) with custom VJP.

The LM's output-layer loss over the vocabulary — the single hottest loss
op in the paper's workload. The grid tiles batch rows with the full vocab
per block: the row-max / logsumexp reduction and the label gather all stay
in VMEM, so logits stream from HBM exactly once (forward) and once more in
backward (recomputing softmax is cheaper than spilling it for the sizes
the LM uses; see DESIGN.md §Perf).

Backward: dlogits = g[:, None] * (softmax(z) - onehot(labels)).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

DEFAULT_BB = 64


def _xent_fwd_kernel(logits_ref, labels_ref, loss_ref):
    z = logits_ref[...]
    labels = labels_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1)) + m[:, 0]
    picked = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
    loss_ref[...] = lse - picked


def _xent_fwd(logits, labels, bb=DEFAULT_BB):
    b, v = logits.shape
    bb = pick_block(b, bb)
    grid = (b // bb,)
    return pl.pallas_call(
        _xent_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=INTERPRET,
    )(logits, labels)


def _xent_bwd_kernel(logits_ref, labels_ref, g_ref, dz_ref):
    z = logits_ref[...]
    labels = labels_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    onehot = (labels[:, None] == jax.lax.iota(jnp.int32, z.shape[-1])[None, :]).astype(
        jnp.float32
    )
    dz_ref[...] = g_ref[...][:, None] * (p - onehot)


def _xent_bwd(res, g, bb=DEFAULT_BB):
    logits, labels = res
    b, v = logits.shape
    bb = pick_block(b, bb)
    grid = (b // bb,)
    dz = pl.pallas_call(
        _xent_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, v), jnp.float32),
        interpret=INTERPRET,
    )(logits, labels, g)
    return dz, None


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Per-example cross entropy. logits: [b,v] f32, labels: [b] i32 -> [b]."""
    return _xent_fwd(logits, labels)


def _softmax_xent_fwd(logits, labels):
    return _xent_fwd(logits, labels), (logits, labels)


def _softmax_xent_bwd(res, g):
    return _xent_bwd(res, g)


softmax_xent.defvjp(_softmax_xent_fwd, _softmax_xent_bwd)

"""Tiled matmul Pallas kernel with custom VJP.

The MXU-facing workhorse: every dense layer and the fused LSTM gate
projection lower through this kernel. The grid is (m/bm, n/bn, k/bk); the
k axis is the innermost (sequential) grid dimension so each (i, j) output
tile stays resident in VMEM while partial products accumulate into it —
the standard Pallas revisiting-accumulator pattern, which is also the
HBM↔VMEM schedule a TPU would want (weight tiles stream, accumulator
stays put).

Backward is two more tiled matmuls: dx = g @ y^T, dy = x^T @ g.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

# Default tile targets: 128 lanes to fill the MXU's systolic array,
# 128 sublane rows to amortize the pipeline.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul_pallas(x, y, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Raw forward: [m,k] @ [k,n] -> [m,n], f32."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {y.shape}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, y)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def matmul(x, y):
    """Differentiable tiled matmul (Pallas fwd + Pallas bwd)."""
    return matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = matmul_pallas(g, y.T)
    dy = matmul_pallas(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)

"""Soft-target cross-entropy Pallas kernel — the paper's distillation loss ψ.

loss[b] = -Σ_v p_t[b,v] · log_softmax(z)[b,v]

with the teacher predictive distribution p_t as soft targets (paper §2:
"we use the cross entropy error treating the teacher predictive
distribution as soft targets"). The same kernel also implements both
label-smoothing baselines of Fig 2a — the caller passes the uniform or
unigram distribution as ``teacher_probs``.

p_t need not sum to one (scaled smoothing targets); the gradient keeps the
general form  dz = g[:,None] · (softmax(z)·Σp − p).

Grid tiles batch rows with the whole vocab resident, mirroring
softmax_xent's schedule so the two losses fuse into one HBM pass of the
logits on TPU.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block

DEFAULT_BB = 64


def _dx_fwd_kernel(logits_ref, probs_ref, loss_ref):
    z = logits_ref[...]
    p = probs_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True)) + m
    logp = z - lse
    loss_ref[...] = -jnp.sum(p * logp, axis=-1)


def _dx_fwd(logits, probs, bb=DEFAULT_BB):
    b, v = logits.shape
    bb = pick_block(b, bb)
    grid = (b // bb,)
    return pl.pallas_call(
        _dx_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=INTERPRET,
    )(logits, probs)


def _dx_bwd_kernel(logits_ref, probs_ref, g_ref, dz_ref):
    z = logits_ref[...]
    p = probs_ref[...]
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    sm = e / jnp.sum(e, axis=-1, keepdims=True)
    sum_p = jnp.sum(p, axis=-1, keepdims=True)
    dz_ref[...] = g_ref[...][:, None] * (sm * sum_p - p)


def _dx_bwd(res, g, bb=DEFAULT_BB):
    logits, probs = res
    b, v = logits.shape
    bb = pick_block(b, bb)
    grid = (b // bb,)
    dz = pl.pallas_call(
        _dx_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((bb, v), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, v), jnp.float32),
        interpret=INTERPRET,
    )(logits, probs, g)
    # Teacher probs are data (stale predictions), never differentiated —
    # matching Algorithm 1 where only θ_i receives gradient.
    return dz, None


@jax.custom_vjp
def distill_xent(logits, teacher_probs):
    """Per-example soft-target cross entropy: [b,v],[b,v] -> [b]."""
    return _dx_fwd(logits, teacher_probs)


def _distill_xent_fwd(logits, probs):
    return _dx_fwd(logits, probs), (logits, probs)


def _distill_xent_bwd(res, g):
    return _dx_bwd(res, g)


distill_xent.defvjp(_distill_xent_fwd, _distill_xent_bwd)

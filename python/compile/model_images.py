"""Layer-2: the image-classification model for the Fig 3 reproduction.

The paper validates codistillation on ImageNet with the Goyal et al.
setup (ResNet, momentum SGD, warmup + step-decay schedule, batch 16384,
75% top-1). The CPU-PJRT substitute (DESIGN.md §4) is a small convnet on
synthetic 10-class prototype images: Fig 3 is a claim about the *training
algorithm* (codistillation enabled after burn-in reaches the baseline's
accuracy in fewer steps and ends slightly higher), which only needs a
stable accuracy-vs-steps curve with tunable headroom.

Matching pieces kept from the paper's setup: momentum SGD, runtime lr
input (the Rust coordinator implements the Goyal warmup + decay
schedule), softmax cross entropy, distillation via soft targets.

Convolutions lower through XLA's conv op (there is no MXU story for tiny
3×3 convs at this scale); all dense layers and both losses go through the
Layer-1 Pallas kernels.
"""

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import distill_xent, matmul, momentum_update, softmax_xent

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ImagesConfig:
    size: int = 16  # image side
    channels: int = 3
    classes: int = 10
    conv1: int = 16
    conv2: int = 32
    dense: int = 128
    batch: int = 64

    def meta(self) -> Dict[str, str]:
        return {
            "model": "images",
            "size": str(self.size),
            "channels": str(self.channels),
            "classes": str(self.classes),
            "conv1": str(self.conv1),
            "conv2": str(self.conv2),
            "dense": str(self.dense),
            "batch": str(self.batch),
            "optimizer": "momentum",
        }

    @property
    def flat_dim(self) -> int:
        return (self.size // 4) * (self.size // 4) * self.conv2


# ------------------------------------------------------------------- params


def init_params(cfg: ImagesConfig, seed) -> Params:
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    ks = jax.random.split(key, 4)

    def conv_init(k, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)

    def fc_init(k, shape):
        lim = jnp.sqrt(6.0 / (shape[0] + shape[1]))
        return jax.random.uniform(k, shape, minval=-lim, maxval=lim)

    return {
        "conv1": {
            "w": conv_init(ks[0], (3, 3, cfg.channels, cfg.conv1)),
            "b": jnp.zeros((cfg.conv1,)),
        },
        "conv2": {
            "w": conv_init(ks[1], (3, 3, cfg.conv1, cfg.conv2)),
            "b": jnp.zeros((cfg.conv2,)),
        },
        "fc1": {
            "w": fc_init(ks[2], (cfg.flat_dim, cfg.dense)),
            "b": jnp.zeros((cfg.dense,)),
        },
        "fc2": {
            "w": fc_init(ks[3], (cfg.dense, cfg.classes)),
            "b": jnp.zeros((cfg.classes,)),
        },
    }


def init_opt(params: Params):
    return {"vel": jax.tree_util.tree_map(jnp.zeros_like, params)}


# ------------------------------------------------------------------ forward


def _conv_block(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + p["b"]
    y = jax.nn.relu(y)
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(cfg: ImagesConfig, params: Params, images):
    """images: [B, S, S, C] f32 -> logits [B, classes]."""
    x = _conv_block(images, params["conv1"])
    x = _conv_block(x, params["conv2"])
    x = x.reshape(images.shape[0], -1)
    x = jax.nn.relu(matmul(x, params["fc1"]["w"]) + params["fc1"]["b"])
    return matmul(x, params["fc2"]["w"]) + params["fc2"]["b"]


def loss_fn(cfg, params, images, labels, teacher_probs, distill_w):
    logits = forward(cfg, params, images)
    hard = jnp.mean(softmax_xent(logits, labels))
    soft = jnp.mean(distill_xent(logits, teacher_probs))
    return hard + distill_w * soft, (hard, soft)


# -------------------------------------------------------------- executables


def _zeros_like_tree(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def _example_params(cfg):
    return _zeros_like_tree(
        jax.eval_shape(lambda s: init_params(cfg, s), jnp.zeros((), jnp.int32))
    )


def example_batch(cfg: ImagesConfig):
    return {
        "images": jnp.zeros((cfg.batch, cfg.size, cfg.size, cfg.channels)),
        "labels": jnp.zeros((cfg.batch,), jnp.int32),
        "teacher_probs": jnp.zeros((cfg.batch, cfg.classes)),
    }


def export_init(cfg: ImagesConfig):
    def fn(seed):
        return {"params": init_params(cfg, seed)}

    return fn, {"seed": jnp.zeros((), jnp.int32)}


def export_train_step(cfg: ImagesConfig):
    def fn(params, opt, images, labels, teacher_probs, distill_w, lr):
        (_, (hard, soft)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, images, labels, teacher_probs, distill_w),
            has_aux=True,
        )(params)
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_v = jax.tree_util.tree_flatten(opt["vel"])[0]
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        new_p, new_v = [], []
        for p, v, g in zip(flat_p, flat_v, flat_g):
            p2, v2 = momentum_update(p, v, g, lr)
            new_p.append(p2)
            new_v.append(v2)
        unf = jax.tree_util.tree_unflatten
        return {
            "params": unf(treedef, new_p),
            "opt": {"vel": unf(treedef, new_v)},
            "loss": hard,
            "distill_loss": soft,
        }

    params = _example_params(cfg)
    b = example_batch(cfg)
    return fn, {
        "params": params,
        "opt": {"vel": _zeros_like_tree(params)},
        **b,
        "distill_w": jnp.zeros(()),
        "lr": jnp.zeros(()),
    }


def export_predict(cfg: ImagesConfig):
    def fn(params, images):
        return {"probs": jax.nn.softmax(forward(cfg, params, images), axis=-1)}

    params = _example_params(cfg)
    b = example_batch(cfg)
    return fn, {"params": params, "images": b["images"]}


def export_eval(cfg: ImagesConfig):
    """Validation loss + top-1 correct count (Fig 3 is accuracy-vs-steps)."""

    def fn(params, images, labels):
        logits = forward(cfg, params, images)
        xent = softmax_xent(logits, labels)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
        return {
            "sum_loss": jnp.sum(xent),
            "correct": correct,
            "count": jnp.asarray(xent.shape[0], jnp.float32),
        }

    params = _example_params(cfg)
    b = example_batch(cfg)
    return fn, {"params": params, "images": b["images"], "labels": b["labels"]}


EXPORTS = {
    "init": export_init,
    "train_step": export_train_step,
    "predict": export_predict,
    "eval": export_eval,
}

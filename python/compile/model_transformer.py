"""Layer-2: decoder-only transformer LM.

The paper predates transformers' dominance and trains an LSTM, but its
codistillation recipe is architecture-agnostic (§2: "using the same
architecture for all the models" is the only requirement). This model
backs the end-to-end `train_e2e` example: a realistically structured
transformer trained through the full Rust coordinator, demonstrating that
the codistillation machinery composes with a second architecture.

Pre-LN blocks, learned positional embeddings, causal attention, Adam.
Projection/MLP matmuls and both losses lower through the Layer-1 Pallas
kernels; the batched attention einsums use XLA's native batched matmul
(a tiled Pallas flash-attention is TPU-profitable only at much longer
sequence lengths than this testbed uses — see DESIGN.md §Perf).

Size is set by :class:`TfmConfig`; the default is small enough to train
a few hundred steps on CPU in minutes. ``aot.py --tfm-preset=100m``
emits a ~100M-parameter bundle with the same interface.
"""

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import adam_update, distill_xent, layernorm, matmul, softmax_xent

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TfmConfig:
    vocab: int = 1024
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    batch: int = 8
    seq: int = 32

    def meta(self) -> Dict[str, str]:
        return {
            "model": "transformer",
            "vocab": str(self.vocab),
            "d_model": str(self.d_model),
            "n_heads": str(self.n_heads),
            "n_layers": str(self.n_layers),
            "d_ff": str(self.d_ff),
            "batch": str(self.batch),
            "seq": str(self.seq),
            "optimizer": "adam",
        }

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# 100M-ish preset for the e2e example at full scale.
PRESET_100M = TfmConfig(
    vocab=32768, d_model=768, n_heads=12, n_layers=12, d_ff=3072, batch=8, seq=128
)


def param_count(cfg: TfmConfig) -> int:
    per_layer = (
        4 * cfg.d_model * cfg.d_model  # qkv + out proj
        + 2 * cfg.d_model * cfg.d_ff  # mlp
        + cfg.d_ff
        + cfg.d_model  # biases (b1, b2)
        + 4 * cfg.d_model  # 2 LNs (gain+bias)
    )
    return cfg.vocab * cfg.d_model + cfg.seq * cfg.d_model + cfg.n_layers * per_layer + 2 * cfg.d_model


# ------------------------------------------------------------------- params


def init_params(cfg: TfmConfig, seed) -> Params:
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    ks = jax.random.split(key, 2 + cfg.n_layers * 6)

    def mat(k, shape):
        lim = jnp.sqrt(6.0 / (shape[0] + shape[1]))
        return jax.random.uniform(k, shape, minval=-lim, maxval=lim)

    d = cfg.d_model
    params: Params = {
        "embedding": jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.seq, d)) * 0.02,
        "ln_f": {"gain": jnp.ones((d,)), "bias": jnp.zeros((d,))},
    }
    for l in range(cfg.n_layers):
        o = 2 + l * 6
        params[f"layer{l}"] = {
            "wq": mat(ks[o + 0], (d, d)),
            "wk": mat(ks[o + 1], (d, d)),
            "wv": mat(ks[o + 2], (d, d)),
            "wo": mat(ks[o + 3], (d, d)),
            "w1": mat(ks[o + 4], (d, cfg.d_ff)),
            "b1": jnp.zeros((cfg.d_ff,)),
            "w2": mat(ks[o + 5], (cfg.d_ff, d)),
            "b2": jnp.zeros((d,)),
            "ln1": {"gain": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "ln2": {"gain": jnp.ones((d,)), "bias": jnp.zeros((d,))},
        }
    return params


def init_opt(params: Params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "step": jnp.zeros(()),
    }


# ------------------------------------------------------------------ forward


def _ln2d(x, p):
    """layernorm kernel over a [B*T, D]-flattened view."""
    b, t, d = x.shape
    return layernorm(x.reshape(b * t, d), p["gain"], p["bias"]).reshape(b, t, d)


def _proj(x, w):
    b, t, d = x.shape
    return matmul(x.reshape(b * t, d), w).reshape(b, t, -1)


def _attention(cfg: TfmConfig, p, x):
    b, t, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    q = _proj(x, p["wq"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    k = _proj(x, p["wk"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    v = _proj(x, p["wv"]).reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return _proj(out, p["wo"])


def forward(cfg: TfmConfig, params: Params, tokens):
    """tokens: [B, T+1] i32 -> (logits [B*T, V], targets [B*T])."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    x = jnp.take(params["embedding"], inputs, axis=0) + params["pos"][None]
    for l in range(cfg.n_layers):
        p = params[f"layer{l}"]
        x = x + _attention(cfg, p, _ln2d(x, p["ln1"]))
        h = _ln2d(x, p["ln2"])
        h = jax.nn.relu(_proj(h, p["w1"]) + p["b1"])
        x = x + _proj(h, p["w2"]) + p["b2"]
    x = _ln2d(x, params["ln_f"])
    b, t, d = x.shape
    logits = matmul(x.reshape(b * t, d), params["embedding"].T)  # tied softmax
    return logits, targets.reshape(b * t)


def loss_fn(cfg, params, tokens, teacher_probs, distill_w):
    logits, targets = forward(cfg, params, tokens)
    hard = jnp.mean(softmax_xent(logits, targets))
    soft = jnp.mean(distill_xent(logits, teacher_probs))
    return hard + distill_w * soft, (hard, soft)


# -------------------------------------------------------------- executables


def _zeros_like_tree(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def _example_params(cfg):
    return _zeros_like_tree(
        jax.eval_shape(lambda s: init_params(cfg, s), jnp.zeros((), jnp.int32))
    )


def export_init(cfg: TfmConfig):
    def fn(seed):
        return {"params": init_params(cfg, seed)}

    return fn, {"seed": jnp.zeros((), jnp.int32)}


def export_train_step(cfg: TfmConfig):
    def fn(params, opt, tokens, teacher_probs, distill_w, lr):
        (_, (hard, soft)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, teacher_probs, distill_w),
            has_aux=True,
        )(params)
        step = opt["step"] + 1.0
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_m = jax.tree_util.tree_flatten(opt["m"])[0]
        flat_v = jax.tree_util.tree_flatten(opt["v"])[0]
        flat_g = jax.tree_util.tree_flatten(grads)[0]
        new_p, new_m, new_v = [], [], []
        for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g):
            p2, m2, v2 = adam_update(p, m, v, g, lr, step)
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        unf = jax.tree_util.tree_unflatten
        return {
            "params": unf(treedef, new_p),
            "opt": {"m": unf(treedef, new_m), "v": unf(treedef, new_v), "step": step},
            "loss": hard,
            "distill_loss": soft,
        }

    params = _example_params(cfg)
    return fn, {
        "params": params,
        "opt": {
            "m": _zeros_like_tree(params),
            "v": _zeros_like_tree(params),
            "step": jnp.zeros(()),
        },
        "tokens": jnp.zeros((cfg.batch, cfg.seq + 1), jnp.int32),
        "teacher_probs": jnp.zeros((cfg.batch * cfg.seq, cfg.vocab)),
        "distill_w": jnp.zeros(()),
        "lr": jnp.zeros(()),
    }


def export_predict(cfg: TfmConfig):
    def fn(params, tokens):
        logits, _ = forward(cfg, params, tokens)
        return {"probs": jax.nn.softmax(logits, axis=-1)}

    params = _example_params(cfg)
    return fn, {
        "params": params,
        "tokens": jnp.zeros((cfg.batch, cfg.seq + 1), jnp.int32),
    }


def export_eval(cfg: TfmConfig):
    def fn(params, tokens):
        logits, targets = forward(cfg, params, tokens)
        xent = softmax_xent(logits, targets)
        return {
            "sum_loss": jnp.sum(xent),
            "count": jnp.asarray(xent.shape[0], jnp.float32),
        }

    params = _example_params(cfg)
    return fn, {
        "params": params,
        "tokens": jnp.zeros((cfg.batch, cfg.seq + 1), jnp.int32),
    }


EXPORTS = {
    "init": export_init,
    "train_step": export_train_step,
    "predict": export_predict,
    "eval": export_eval,
}

"""AOT lowering: JAX models -> HLO-text artifact bundles for the Rust side.

Run once via ``make artifacts``. For every configured (model, config) pair
this writes ``artifacts/<bundle>/``:

* ``<exec>.hlo.txt``  — XLA HLO **text** (NOT a serialized proto:
  xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids; the text
  parser reassigns ids — see /opt/xla-example/README.md),
* ``<exec>.spec.txt`` — the flattened I/O signature (names/dtypes/shapes
  in exact flattened-pytree order) parsed by ``rust/src/runtime/spec.rs``,
* ``bundle.txt``      — model hyperparameters for ``runtime/bundle.rs``.

Incremental: a bundle is skipped when its ``fingerprint.txt`` (config +
source mtimes) is unchanged.

Usage: ``python -m compile.aot [--out DIR] [--only BUNDLE[,BUNDLE...]]
[--tfm-preset {small,100m}] [--force]``
"""

import argparse
import hashlib
import os
import sys
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model_criteo, model_images, model_lm, model_transformer

# --------------------------------------------------------------- bundle set


def bundle_configs(tfm_preset: str) -> Dict[str, Any]:
    """All artifact bundles. Keys are directory names under artifacts/."""
    bundles: Dict[str, Any] = {}

    # Primary LM config (the "128-GPU-group equivalent", DESIGN.md §4):
    # effective batch 64 for fused group steps, per-worker batch 8 for the
    # real allreduce path.
    base = dict(vocab=512, embed=32, hidden=64, layers=2, unroll=16)
    # Fig 1 sweep: effective batch = 32..256 (scaled 1:16 from the paper's
    # 4096..32768), one fused bundle per size.
    for eff in (32, 64, 128, 256):
        bundles[f"lm_b{eff}"] = ("lm", model_lm.LmConfig(batch=eff, **base))
    # Per-worker bundle for the gradient/allreduce path.
    bundles["lm_w8"] = ("lm", model_lm.LmConfig(batch=8, **base))

    bundles["criteo"] = ("criteo", model_criteo.CriteoConfig())
    bundles["images"] = ("images", model_images.ImagesConfig())

    tfm_cfg = (
        model_transformer.PRESET_100M
        if tfm_preset == "100m"
        else model_transformer.TfmConfig()
    )
    bundles["tfm"] = ("transformer", tfm_cfg)
    return bundles


MODELS = {
    "lm": model_lm,
    "criteo": model_criteo,
    "images": model_images,
    "transformer": model_transformer,
}

# ----------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "i32",
    jnp.dtype("uint32"): "u32",
}


def _path_name(prefix: str, path) -> str:
    parts = [prefix]
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _leaf_lines(tag: str, prefix: str, tree) -> list:
    lines = []
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        dt = _DTYPE_NAMES.get(jnp.dtype(leaf.dtype))
        if dt is None:
            raise ValueError(f"unsupported dtype {leaf.dtype} at {prefix}{path}")
        dims = ",".join(str(d) for d in leaf.shape) if leaf.shape else "-"
        lines.append(f"{tag} {_path_name(prefix, path)} {dt} {dims}")
    return lines


def make_spec(name: str, args: Dict[str, Any], out_tree, meta: Dict[str, str]) -> str:
    lines = ["spec-version 1", f"name {name}"]
    for k, v in meta.items():
        lines.append(f"meta {k} {v}")
    for argname, tree in args.items():
        lines.extend(_leaf_lines("in", argname, tree))
    lines.extend(_leaf_lines("out", "", out_tree))
    # outputs get a leading "." from the empty prefix; strip it
    lines = [l[:4] + l[4:].lstrip(".") if l.startswith("out ") else l for l in lines]
    return "\n".join(lines) + "\n"


def lower_export(name: str, fn, example_args: Dict[str, Any]):
    args = list(example_args.values())
    lowered = jax.jit(fn).lower(*args)
    out_shape = jax.eval_shape(fn, *args)
    return to_hlo_text(lowered), out_shape


# -------------------------------------------------------------- driver


def fingerprint(model_name: str, cfg) -> str:
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    h.update(repr(cfg).encode())
    for fname in sorted(os.listdir(here)):
        if fname.endswith(".py"):
            h.update(fname.encode())
            with open(os.path.join(here, fname), "rb") as f:
                h.update(f.read())
    kdir = os.path.join(here, "kernels")
    for fname in sorted(os.listdir(kdir)):
        if fname.endswith(".py"):
            with open(os.path.join(kdir, fname), "rb") as f:
                h.update(f.read())
    return h.hexdigest()


def build_bundle(out_dir: str, bundle: str, model_name: str, cfg, force: bool) -> bool:
    bdir = os.path.join(out_dir, bundle)
    os.makedirs(bdir, exist_ok=True)
    fp = fingerprint(model_name, cfg)
    fp_path = os.path.join(bdir, "fingerprint.txt")
    if not force and os.path.exists(fp_path):
        with open(fp_path) as f:
            if f.read().strip() == fp:
                print(f"[aot] {bundle}: up to date")
                return False
    model = MODELS[model_name]
    meta = cfg.meta()
    for exec_name, export in model.EXPORTS.items():
        fn, example_args = export(cfg)
        hlo, out_shape = lower_export(exec_name, fn, example_args)
        with open(os.path.join(bdir, f"{exec_name}.hlo.txt"), "w") as f:
            f.write(hlo)
        spec = make_spec(exec_name, example_args, out_shape, meta)
        with open(os.path.join(bdir, f"{exec_name}.spec.txt"), "w") as f:
            f.write(spec)
        print(f"[aot] {bundle}/{exec_name}: {len(hlo)} chars")
    with open(os.path.join(bdir, "bundle.txt"), "w") as f:
        for k, v in meta.items():
            f.write(f"{k} {v}\n")
    with open(fp_path, "w") as f:
        f.write(fp + "\n")
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="comma-separated bundle names")
    ap.add_argument("--tfm-preset", choices=["small", "100m"], default="small")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    bundles = bundle_configs(args.tfm_preset)
    selected = set(args.only.split(",")) if args.only else set(bundles)
    unknown = selected - set(bundles)
    if unknown:
        sys.exit(f"unknown bundles: {sorted(unknown)}; available: {sorted(bundles)}")

    built = 0
    for bundle, (model_name, cfg) in bundles.items():
        if bundle not in selected:
            continue
        built += build_bundle(args.out, bundle, model_name, cfg, args.force)
    print(f"[aot] done; {built} bundle(s) rebuilt at {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()

"""lstm_gates kernel vs oracle: values, grads, and cell-dynamics invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels
from compile.kernels import ref

rows = st.sampled_from([1, 2, 8, 32, 128])
hidden = st.sampled_from([1, 2, 4, 16, 64])


def _case(seed, b, h):
    kp, kc = jax.random.split(jax.random.PRNGKey(seed))
    pre = jax.random.normal(kp, (b, 4 * h), dtype=jnp.float32) * 2.0
    c = jax.random.normal(kc, (b, h), dtype=jnp.float32)
    return pre, c


@given(b=rows, h=hidden, seed=st.integers(0, 2**16))
def test_lstm_gates_matches_ref(b, h, seed):
    pre, c = _case(seed, b, h)
    hk, ck = kernels.lstm_gates(pre, c)
    hr, cr = ref.lstm_gates(pre, c)
    np.testing.assert_allclose(hk, hr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ck, cr, rtol=1e-4, atol=1e-5)


@given(b=st.sampled_from([2, 16]), h=st.sampled_from([2, 8, 32]),
       seed=st.integers(0, 2**16))
def test_lstm_gates_grads_match_ref(b, h, seed):
    pre, c = _case(seed, b, h)

    def lk(p, cc):
        hn, cn = kernels.lstm_gates(p, cc)
        return jnp.sum(hn**2) + jnp.sum(jnp.tanh(cn))

    def lr(p, cc):
        hn, cn = ref.lstm_gates(p, cc)
        return jnp.sum(hn**2) + jnp.sum(jnp.tanh(cn))

    for i in range(2):
        gk = jax.grad(lk, argnums=i)(pre, c)
        gr = jax.grad(lr, argnums=i)(pre, c)
        np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-4)


def test_hidden_state_bounded():
    # |h| <= 1 because h = sigmoid(o) * tanh(c).
    pre, c = _case(0, 64, 16)
    hn, _ = kernels.lstm_gates(pre * 10.0, c * 10.0)
    assert np.all(np.abs(np.asarray(hn)) <= 1.0 + 1e-6)


def test_forget_gate_extremes():
    # With f-gate pre-activation driven to -inf the old cell is erased;
    # with +inf it is fully kept (plus the input-gate contribution).
    b, h = 4, 8
    pre, c = _case(1, b, h)
    big = jnp.full((b, h), 50.0)
    pre_keep = pre.at[:, h : 2 * h].set(big)
    pre_drop = pre.at[:, h : 2 * h].set(-big)
    _, c_keep = kernels.lstm_gates(pre_keep, c)
    _, c_drop = kernels.lstm_gates(pre_drop, c)
    i = jax.nn.sigmoid(pre[:, :h])
    g = jnp.tanh(pre[:, 2 * h : 3 * h])
    np.testing.assert_allclose(c_keep, c + i * g, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c_drop, i * g, rtol=1e-4, atol=1e-5)


def test_composed_lstm_cell_matches_ref():
    # Full LayerNorm-LSTM cell composed from the three kernels equals the
    # pure-jnp composed reference (value + grad wrt weights).
    b, e, h = 8, 12, 16
    keys = jax.random.split(jax.random.PRNGKey(7), 7)
    x = jax.random.normal(keys[0], (b, e))
    hp = jax.random.normal(keys[1], (b, h))
    cp = jax.random.normal(keys[2], (b, h))
    w = jax.random.normal(keys[3], (e + h, 4 * h)) * 0.1
    bb = jax.random.normal(keys[4], (4 * h,)) * 0.1
    gain = jnp.ones(4 * h) + jax.random.normal(keys[5], (4 * h,)) * 0.05
    bias = jax.random.normal(keys[6], (4 * h,)) * 0.05

    def cell_k(w, b_):
        xa = jnp.concatenate([x, hp], axis=-1)
        pre = kernels.matmul(xa, w) + b_
        pre = kernels.layernorm(pre, gain, bias)
        hn, cn = kernels.lstm_gates(pre, cp)
        return jnp.sum(hn**2) + jnp.sum(cn)

    def cell_r(w, b_):
        hn, cn = ref.lstm_cell(x, hp, cp, w, b_, gain, bias)
        return jnp.sum(hn**2) + jnp.sum(cn)

    np.testing.assert_allclose(cell_k(w, bb), cell_r(w, bb), rtol=1e-4)
    gw_k, gb_k = jax.grad(cell_k, argnums=(0, 1))(w, bb)
    gw_r, gb_r = jax.grad(cell_r, argnums=(0, 1))(w, bb)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gb_k, gb_r, rtol=1e-3, atol=1e-3)

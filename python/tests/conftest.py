import os
import sys

# Make `compile` importable when pytest runs from the repo root or python/.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from hypothesis import settings

# Interpret-mode pallas is slow; keep sweeps small but meaningful and kill
# the per-example deadline (first-call tracing dominates).
settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")

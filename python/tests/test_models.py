"""Layer-2 model tests: shapes, semantics (EOD reset, state carry,
distill-term identities), and short-horizon learnability in pure JAX
before AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model_criteo, model_images, model_lm, model_transformer


def small_lm():
    return model_lm.LmConfig(vocab=64, embed=8, hidden=16, layers=2, batch=4, unroll=8)


def test_lm_init_shapes_and_determinism():
    cfg = small_lm()
    p1 = model_lm.init_params(cfg, jnp.asarray(3, jnp.int32))
    p2 = model_lm.init_params(cfg, jnp.asarray(3, jnp.int32))
    p3 = model_lm.init_params(cfg, jnp.asarray(4, jnp.int32))
    assert p1["embedding"].shape == (64, 8)
    assert p1["layer0"]["w"].shape == (8 + 16, 64)
    assert p1["layer1"]["w"].shape == (16 + 16, 64)
    assert p1["out"]["w"].shape == (16, 64)
    np.testing.assert_array_equal(p1["embedding"], p2["embedding"])
    assert not np.array_equal(p1["embedding"], p3["embedding"])
    # forget-gate bias +1
    np.testing.assert_array_equal(p1["layer0"]["b"][16:32], np.ones(16))


def test_lm_forward_shapes_and_state_carry():
    cfg = small_lm()
    params = model_lm.init_params(cfg, jnp.asarray(0, jnp.int32))
    state = model_lm.init_state(cfg)
    tokens = jnp.ones((cfg.batch, cfg.unroll + 1), jnp.int32) * 5
    logits, targets, new_state = model_lm.forward(cfg, params, state, tokens)
    assert logits.shape == (cfg.unroll * cfg.batch, cfg.vocab)
    assert targets.shape == (cfg.unroll * cfg.batch,)
    assert new_state["h"].shape == (cfg.layers, cfg.batch, cfg.hidden)
    # state actually changes
    assert not np.allclose(new_state["h"], state["h"])
    # and feeding the carried state changes the next forward's output
    logits2a, _, _ = model_lm.forward(cfg, params, new_state, tokens)
    logits2b, _, _ = model_lm.forward(cfg, params, state, tokens)
    assert not np.allclose(logits2a, logits2b)


def test_lm_eod_resets_state():
    cfg = small_lm()
    params = model_lm.init_params(cfg, jnp.asarray(0, jnp.int32))
    # random nonzero state
    key = jax.random.PRNGKey(1)
    state = {
        "h": jax.random.normal(key, (cfg.layers, cfg.batch, cfg.hidden)),
        "c": jax.random.normal(key, (cfg.layers, cfg.batch, cfg.hidden)),
    }
    zero_state = model_lm.init_state(cfg)
    # first input token is EOD -> state is zeroed before the first cell
    eod_first = jnp.full((cfg.batch, cfg.unroll + 1), 7, jnp.int32)
    eod_first = eod_first.at[:, 0].set(cfg.eod_id)
    la, _, _ = model_lm.forward(cfg, params, state, eod_first)
    lb, _, _ = model_lm.forward(cfg, params, zero_state, eod_first)
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)
    # without EOD the states matter
    no_eod = jnp.full((cfg.batch, cfg.unroll + 1), 7, jnp.int32)
    la2, _, _ = model_lm.forward(cfg, params, state, no_eod)
    lb2, _, _ = model_lm.forward(cfg, params, zero_state, no_eod)
    assert not np.allclose(la2, lb2)


def test_lm_distill_zero_weight_is_plain_loss():
    cfg = small_lm()
    params = model_lm.init_params(cfg, jnp.asarray(0, jnp.int32))
    state = model_lm.init_state(cfg)
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (cfg.batch, cfg.unroll + 1), 3, cfg.vocab)
    probs = jax.nn.softmax(jax.random.normal(key, (cfg.unroll * cfg.batch, cfg.vocab)))
    l0, (hard0, _, _) = model_lm.loss_fn(cfg, params, state, tokens, probs, jnp.float32(0.0))
    np.testing.assert_allclose(l0, hard0, rtol=1e-6)
    l1, (hard1, soft1, _) = model_lm.loss_fn(cfg, params, state, tokens, probs, jnp.float32(0.5))
    np.testing.assert_allclose(l1, hard1 + 0.5 * soft1, rtol=1e-6)


def test_lm_learns_constant_sequence():
    # A few Adam steps on a repetitive sequence should slash the loss.
    cfg = small_lm()
    init_fn, _ = model_lm.export_init(cfg)
    params = init_fn(jnp.asarray(1, jnp.int32))["params"]
    state = model_lm.init_state(cfg)
    opt = model_lm.init_opt(params)
    tokens = jnp.tile(jnp.arange(3, 3 + cfg.unroll + 1, dtype=jnp.int32), (cfg.batch, 1))
    probs = jnp.zeros((cfg.unroll * cfg.batch, cfg.vocab))

    fn, _ = model_lm.export_train_step(cfg)
    step = jax.jit(fn)
    first = None
    for _ in range(30):
        out = step(params, opt, state, tokens, probs, jnp.float32(0.0), jnp.float32(0.01))
        params, opt, state = out["params"], out["opt"], out["state"]
        if first is None:
            first = float(out["loss"])
    last = float(out["loss"])
    assert last < first * 0.7, f"{first} -> {last}"


def test_criteo_two_class_identity():
    cfg = model_criteo.CriteoConfig(buckets=10, batch=4)
    params = model_criteo.init_params(cfg, jnp.asarray(0, jnp.int32))
    dense = jnp.ones((4, cfg.n_dense))
    cat = jnp.zeros((4, cfg.n_cat), jnp.int32)
    logits = model_criteo.forward(cfg, params, dense, cat)
    assert logits.shape == (4,)
    # sigmoid(z) == softmax([0, z])[1]
    z2 = model_criteo._two_class(logits)
    np.testing.assert_allclose(
        jax.nn.sigmoid(logits), jax.nn.softmax(z2, axis=-1)[:, 1], rtol=1e-5
    )


def test_criteo_embedding_offsets_separate_fields():
    cfg = model_criteo.CriteoConfig(buckets=10, batch=2)
    params = model_criteo.init_params(cfg, jnp.asarray(0, jnp.int32))
    dense = jnp.zeros((2, cfg.n_dense))
    # same bucket id in different fields must hit different embeddings
    cat_a = jnp.zeros((2, cfg.n_cat), jnp.int32)
    cat_b = cat_a.at[:, 1].set(0).at[:, 0].set(0)
    cat_c = cat_a.at[:, 0].set(1)
    la = model_criteo.forward(cfg, params, dense, cat_a)
    lc = model_criteo.forward(cfg, params, dense, cat_c)
    assert not np.allclose(la, lc)
    np.testing.assert_allclose(
        la, model_criteo.forward(cfg, params, dense, cat_b), rtol=1e-6
    )


def test_images_forward_and_loss():
    cfg = model_images.ImagesConfig(size=8, batch=4)
    params = model_images.init_params(cfg, jnp.asarray(0, jnp.int32))
    images = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8, 3))
    logits = model_images.forward(cfg, params, images)
    assert logits.shape == (4, cfg.classes)
    labels = jnp.array([0, 1, 2, 3], jnp.int32)
    probs = jnp.full((4, cfg.classes), 0.1)
    loss, (hard, soft) = model_images.loss_fn(
        cfg, params, images, labels, probs, jnp.float32(0.25)
    )
    np.testing.assert_allclose(loss, hard + 0.25 * soft, rtol=1e-6)


def test_transformer_causality():
    cfg = model_transformer.TfmConfig(
        vocab=32, d_model=16, n_heads=2, n_layers=1, d_ff=32, batch=2, seq=8
    )
    params = model_transformer.init_params(cfg, jnp.asarray(0, jnp.int32))
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (2, cfg.seq + 1), 0, 32)
    logits, _ = model_transformer.forward(cfg, params, tokens)
    logits = logits.reshape(2, cfg.seq, 32)
    # Changing a future token must not change past logits.
    tokens2 = tokens.at[:, cfg.seq - 1].set((tokens[:, cfg.seq - 1] + 1) % 32)
    logits2, _ = model_transformer.forward(cfg, params, tokens2)
    logits2 = logits2.reshape(2, cfg.seq, 32)
    np.testing.assert_allclose(
        logits[:, : cfg.seq - 2], logits2[:, : cfg.seq - 2], rtol=1e-4, atol=1e-5
    )
    assert not np.allclose(logits[:, cfg.seq - 1], logits2[:, cfg.seq - 1])


def test_transformer_param_count_formula():
    cfg = model_transformer.TfmConfig()
    params = model_transformer.init_params(cfg, jnp.asarray(0, jnp.int32))
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert actual == model_transformer.param_count(cfg)
    # the 100m preset really is ~100M
    assert 8e7 < model_transformer.param_count(model_transformer.PRESET_100M) < 1.6e8

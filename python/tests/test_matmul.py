"""matmul kernel vs pure-jnp oracle: values and both gradients."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.common import pick_block

dims = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 64, 96, 128, 160, 256])


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        kernels.matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-5
    )


@given(m=st.sampled_from([2, 8, 32]), k=st.sampled_from([4, 16, 96]),
       n=st.sampled_from([2, 8, 64]), seed=st.integers(0, 2**16))
def test_matmul_grads_match_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))

    def loss_k(x, y):
        return jnp.sum(kernels.matmul(x, y) ** 2)

    def loss_r(x, y):
        return jnp.sum(ref.matmul(x, y) ** 2)

    gx_k, gy_k = jax.grad(loss_k, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(loss_r, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gy_k, gy_r, rtol=1e-3, atol=1e-4)


def test_matmul_identity():
    x = _rand(0, (8, 8))
    np.testing.assert_allclose(
        kernels.matmul(x, jnp.eye(8)), x, rtol=1e-5, atol=1e-6
    )


def test_matmul_jit_compatible():
    x = _rand(1, (16, 32))
    y = _rand(2, (32, 8))
    out = jax.jit(kernels.matmul)(x, y)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-4, atol=1e-5)


@given(dim=st.integers(1, 300), target=st.integers(1, 256))
def test_pick_block_divides(dim, target):
    b = pick_block(dim, target)
    assert 1 <= b <= min(dim, target)
    assert dim % b == 0


def test_pick_block_power_of_two_alignment():
    assert pick_block(256, 128) == 128
    assert pick_block(64, 128) == 64
    assert pick_block(96, 128) == 96

"""Fused optimizer kernels vs oracles + multi-step trajectory equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels
from compile.kernels import ref

shapes = st.sampled_from([(3,), (7, 13), (4, 8, 2), (128,), (96, 5)])


def _tensors(seed, shape):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.normal(ks[0], shape, dtype=jnp.float32)
    g = jax.random.normal(ks[1], shape, dtype=jnp.float32)
    aux = jnp.abs(jax.random.normal(ks[2], shape, dtype=jnp.float32))
    return p, g, aux


@given(shape=shapes, seed=st.integers(0, 2**16),
       step=st.integers(1, 1000), lr=st.sampled_from([1e-4, 1e-2, 0.3]))
def test_adam_matches_ref(shape, seed, step, lr):
    p, g, _ = _tensors(seed, shape)
    m = jnp.zeros_like(p) + 0.1
    v = jnp.zeros_like(p) + 0.2
    got = kernels.adam_update(p, m, v, g, jnp.float32(lr), jnp.float32(step))
    want = ref.adam_update(p, m, v, g, lr, 0.9, 0.999, 1e-8, float(step))
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


@given(shape=shapes, seed=st.integers(0, 2**16),
       lr=st.sampled_from([1e-3, 1e-2]))
def test_adagrad_matches_ref(shape, seed, lr):
    p, g, acc = _tensors(seed, shape)
    got = kernels.adagrad_update(p, acc, g, jnp.float32(lr))
    want = ref.adagrad_update(p, acc, g, lr, 1e-10)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


@given(shape=shapes, seed=st.integers(0, 2**16),
       lr=st.sampled_from([1e-2, 0.1]))
def test_momentum_matches_ref(shape, seed, lr):
    p, g, vel = _tensors(seed, shape)
    got = kernels.momentum_update(p, vel, g, jnp.float32(lr))
    want = ref.momentum_update(p, vel, g, lr, 0.9)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_adam_trajectory_decreases_quadratic():
    # 20 Adam steps on f(p) = |p|^2 shrink the norm.
    p = jnp.array([2.0, -3.0, 1.5, 4.0])
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    n0 = float(jnp.linalg.norm(p))
    for t in range(1, 21):
        g = 2.0 * p
        p, m, v = kernels.adam_update(p, m, v, g, jnp.float32(0.1), jnp.float32(t))
    assert float(jnp.linalg.norm(p)) < n0 * 0.5


def test_adagrad_accumulator_monotone():
    p, g, acc = _tensors(0, (32,))
    _, acc2 = kernels.adagrad_update(p, acc, g, jnp.float32(0.01))
    assert np.all(np.asarray(acc2) >= np.asarray(acc) - 1e-7)


def test_momentum_accumulates_direction():
    # Constant gradient: velocity converges toward g / (1 - mu).
    p = jnp.zeros((8,))
    vel = jnp.zeros((8,))
    g = jnp.ones((8,))
    for _ in range(60):
        p, vel = kernels.momentum_update(p, vel, g, jnp.float32(0.0))
    np.testing.assert_allclose(vel, jnp.full((8,), 1.0 / (1.0 - 0.9)), rtol=1e-2)

"""softmax_xent + distill_xent kernels vs oracles, plus the algebraic
relationships the codistillation loss relies on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels
from compile.kernels import ref

batch = st.sampled_from([1, 2, 8, 32, 64])
vocab = st.sampled_from([2, 8, 50, 128, 512])


def _logits(seed, b, v, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, v), dtype=jnp.float32) * scale


@given(b=batch, v=vocab, seed=st.integers(0, 2**16))
def test_softmax_xent_matches_ref(b, v, seed):
    z = _logits(seed, b, v)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, v)
    np.testing.assert_allclose(
        kernels.softmax_xent(z, labels), ref.softmax_xent(z, labels),
        rtol=1e-4, atol=1e-5,
    )


@given(b=st.sampled_from([2, 16]), v=st.sampled_from([8, 64, 256]),
       seed=st.integers(0, 2**16))
def test_softmax_xent_grad_matches_ref(b, v, seed):
    z = _logits(seed, b, v)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, v)
    gk = jax.grad(lambda z: kernels.softmax_xent(z, labels).mean())(z)
    gr = jax.grad(lambda z: ref.softmax_xent(z, labels).mean())(z)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-5)


@given(b=batch, v=vocab, seed=st.integers(0, 2**16))
def test_distill_xent_matches_ref(b, v, seed):
    z = _logits(seed, b, v)
    probs = jax.nn.softmax(_logits(seed + 1, b, v))
    np.testing.assert_allclose(
        kernels.distill_xent(z, probs), ref.distill_xent(z, probs),
        rtol=1e-4, atol=1e-4,
    )


@given(b=st.sampled_from([2, 16]), v=st.sampled_from([8, 64, 256]),
       seed=st.integers(0, 2**16))
def test_distill_xent_grad_matches_ref(b, v, seed):
    z = _logits(seed, b, v)
    probs = jax.nn.softmax(_logits(seed + 1, b, v))
    gk = jax.grad(lambda z: kernels.distill_xent(z, probs).mean())(z)
    gr = jax.grad(lambda z: ref.distill_xent(z, probs).mean())(z)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-5)


def test_distill_with_onehot_equals_hard_xent():
    # psi with a one-hot "teacher" degenerates to the hard loss phi —
    # the identity that lets one artifact serve both baselines.
    b, v = 16, 32
    z = _logits(3, b, v)
    labels = jax.random.randint(jax.random.PRNGKey(4), (b,), 0, v)
    onehot = jax.nn.one_hot(labels, v)
    np.testing.assert_allclose(
        kernels.distill_xent(z, onehot), kernels.softmax_xent(z, labels),
        rtol=1e-4, atol=1e-5,
    )


def test_distill_unnormalized_scales_gradient():
    # Scaled teacher distribution scales both the loss and its gradient —
    # the property the burn-in ramp (weight * probs) relies on.
    b, v = 8, 16
    z = _logits(5, b, v)
    probs = jax.nn.softmax(_logits(6, b, v))
    l1 = kernels.distill_xent(z, probs)
    l2 = kernels.distill_xent(z, probs * 0.5)
    np.testing.assert_allclose(l2, 0.5 * l1, rtol=1e-4, atol=1e-5)
    g1 = jax.grad(lambda z: kernels.distill_xent(z, probs).sum())(z)
    g2 = jax.grad(lambda z: kernels.distill_xent(z, probs * 0.5).sum())(z)
    np.testing.assert_allclose(g2, 0.5 * g1, rtol=1e-4, atol=1e-5)


def test_distill_minimized_at_teacher():
    # Over a simplex-constrained softmax, psi(p_t, z) is minimized when
    # softmax(z) == p_t; check the gradient vanishes there.
    b, v = 4, 8
    logits = _logits(7, b, v)
    probs = jax.nn.softmax(logits)
    g = jax.grad(lambda z: kernels.distill_xent(z, probs).sum())(logits)
    np.testing.assert_allclose(g, jnp.zeros_like(g), atol=1e-5)


def test_xent_uniform_logits():
    # All-equal logits: loss is log(v) for every label.
    b, v = 8, 64
    z = jnp.zeros((b, v))
    labels = jnp.arange(b, dtype=jnp.int32) % v
    np.testing.assert_allclose(
        kernels.softmax_xent(z, labels), jnp.full((b,), np.log(v)),
        rtol=1e-5,
    )

"""layernorm kernel vs oracle: values + grads wrt x, gain, bias."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels
from compile.kernels import ref

rows = st.sampled_from([1, 2, 4, 8, 32, 96, 128, 256])
feats = st.sampled_from([2, 4, 8, 64, 128, 256])


def _case(seed, b, d):
    kx, kg, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (b, d), dtype=jnp.float32) * 3.0 + 0.5
    gain = jax.random.normal(kg, (d,), dtype=jnp.float32) * 0.2 + 1.0
    bias = jax.random.normal(kb, (d,), dtype=jnp.float32) * 0.1
    return x, gain, bias


@given(b=rows, d=feats, seed=st.integers(0, 2**16))
def test_layernorm_matches_ref(b, d, seed):
    x, gain, bias = _case(seed, b, d)
    np.testing.assert_allclose(
        kernels.layernorm(x, gain, bias),
        ref.layernorm(x, gain, bias),
        rtol=1e-4,
        atol=1e-5,
    )


@given(b=st.sampled_from([2, 16, 64]), d=st.sampled_from([4, 32, 128]),
       seed=st.integers(0, 2**16))
def test_layernorm_grads_match_ref(b, d, seed):
    x, gain, bias = _case(seed, b, d)

    def lk(x, g, bb):
        return jnp.sum(kernels.layernorm(x, g, bb) ** 2)

    def lr(x, g, bb):
        return jnp.sum(ref.layernorm(x, g, bb) ** 2)

    for i in range(3):
        gk = jax.grad(lk, argnums=i)(x, gain, bias)
        gr = jax.grad(lr, argnums=i)(x, gain, bias)
        np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-3)


def test_layernorm_normalizes():
    x, _, _ = _case(0, 32, 64)
    y = kernels.layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.mean(np.asarray(y), axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(np.asarray(y), axis=-1), 1.0, atol=1e-3)


def test_layernorm_shift_invariant():
    x, gain, bias = _case(1, 8, 32)
    y1 = kernels.layernorm(x, gain, bias)
    y2 = kernels.layernorm(x + 100.0, gain, bias)
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
